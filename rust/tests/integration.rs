//! Cross-module integration tests: config -> workload -> engine ->
//! metrics, with the paper's qualitative orderings asserted end-to-end
//! (these are the "shape" claims EXPERIMENTS.md records quantitatively).

use pcr::bench::scenario::{paper_config, Scale};
use pcr::cache::tier::Tier;
use pcr::config::ExperimentConfig;
use pcr::serve::engine::{self, RunOutcome};
use pcr::serve::scheduler::{plan_movement, unpin_plan};
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;

fn small_cfg(rate: f64) -> ExperimentConfig {
    let mut cfg = paper_config("llama2-7b", "a6000", true, rate, Scale::Lite);
    cfg.n_inputs = 60;
    cfg.n_requests = 150;
    cfg.n_docs = 250;
    cfg.mean_doc_tokens = 700;
    // re-derive tier pressure for the shrunk dataset (paper_config sized
    // tiers for the default Lite dataset): GPU 3%, DRAM 25%, SSD 150%
    let kv = pcr::hw::spec::model_spec(&cfg.model)
        .unwrap()
        .kv_bytes_per_token();
    let distinct = cfg.n_inputs as u64 * (2 * cfg.mean_doc_tokens + 64) as u64;
    cfg.gpu_bytes = distinct * kv * 3 / 100;
    cfg.dram_bytes = distinct * kv / 4;
    cfg.ssd_bytes = distinct * kv * 3 / 2;
    cfg
}

fn run_named(cfg: &ExperimentConfig, wl: &Workload, name: &str) -> RunOutcome {
    let spec = SystemSpec::named(name, cfg.prefetch_window).unwrap();
    engine::run(cfg, &spec, wl)
}

#[test]
fn paper_ordering_holds_end_to_end() {
    let cfg = small_cfg(0.9);
    let wl = Workload::build(&cfg);
    let vllm = run_named(&cfg, &wl, "vllm");
    let ccache = run_named(&cfg, &wl, "ccache");
    let sccache = run_named(&cfg, &wl, "sccache");
    let lmcache = run_named(&cfg, &wl, "lmcache");
    let pcr = run_named(&cfg, &wl, "pcr");
    // the paper's Fig 14/17 ordering
    assert!(pcr.report.ttft.mean <= lmcache.report.ttft.mean * 1.001);
    assert!(lmcache.report.ttft.mean <= sccache.report.ttft.mean * 1.001);
    assert!(ccache.report.ttft.mean <= vllm.report.ttft.mean * 1.001);
    assert!(pcr.report.ttft.mean < vllm.report.ttft.mean);
    // tier hit structure: vllm only GPU; ccache no SSD; sccache all three
    assert_eq!(vllm.reused_dram_chunks + vllm.reused_ssd_chunks, 0);
    assert_eq!(ccache.reused_ssd_chunks, 0);
    assert!(sccache.reused_ssd_chunks > 0, "SSD tier must serve hits");
}

#[test]
fn token_conservation_across_engine() {
    // every request's reused + computed tokens == its input length
    let cfg = small_cfg(0.8);
    let wl = Workload::build(&cfg);
    let out = run_named(&cfg, &wl, "pcr");
    assert_eq!(out.report.finished, wl.len());
    // aggregate conservation via the reuse ratio
    let total: f64 = wl.items.iter().map(|i| i.tokens.len() as f64).sum();
    let mean_reuse = out.report.mean_reuse_ratio;
    assert!((0.0..=1.0).contains(&mean_reuse));
    assert!(total > 0.0);
}

#[test]
fn config_file_drives_full_run() {
    let dir = std::env::temp_dir().join(format!("pcr-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
model = "qwen2.5-7b"
platform = "rtx4090"
system = "pcr"
[cache]
chunk_tokens = 128
gpu_bytes = 1GiB
dram_bytes = 4GiB
ssd_bytes = 32GiB
prefetch_window = 6
[workload]
rate = 1.0
n_inputs = 40
n_requests = 100
[corpus]
n_docs = 200
n_topics = 16
mean_doc_tokens = 500
"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.model, "qwen2.5-7b");
    assert_eq!(cfg.prefetch_window, 6);
    let wl = Workload::build(&cfg);
    let out = run_named(&cfg, &wl, &cfg.system);
    assert_eq!(out.report.finished, 100);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn movement_plan_consistent_with_residency() {
    // Algorithm 1's plan must agree with the tree it was derived from.
    let cfg = small_cfg(0.8);
    let wl = Workload::build(&cfg);
    let model = pcr::hw::spec::model_spec(&cfg.model).unwrap();
    let platform = pcr::hw::spec::platform_spec(&cfg.platform).unwrap();
    let spec = SystemSpec::named("pcr", 4).unwrap();
    let mut cache = pcr::cache::engine::CacheEngine::new(
        engine::cache_config(&cfg, &spec, &model, &platform));
    let chunk_bytes = model.kv_bytes_per_token() * cfg.chunk_tokens as u64;

    // insert the first item's chain into DRAM, then plan the second
    let mut parent = None;
    for key in &wl.items[0].chain.keys {
        parent = cache.insert(parent, *key, chunk_bytes, Tier::Dram);
        if parent.is_none() {
            break;
        }
    }
    for item in &wl.items[1..20] {
        let plan = plan_movement(&mut cache, &item.chain);
        assert_eq!(
            plan.from_gpu + plan.from_dram + plan.from_ssd,
            plan.matched.len()
        );
        assert_eq!(
            plan.reused_tokens + plan.computed_tokens,
            item.chain.total_tokens
        );
        assert!(plan.computed_chunks <= item.chain.n_chunks());
        for (i, id) in plan.matched.iter().enumerate() {
            assert_eq!(cache.tree.node(*id).key, item.chain.keys[i]);
            assert!(cache.tree.node(*id).pins > 0, "plan must pin");
        }
        unpin_plan(&mut cache, &plan);
        cache.check_accounting().unwrap();
    }
}

#[test]
fn prefetch_reduces_ssd_wait() {
    let cfg = small_cfg(1.2); // heavy load: deep queue
    let wl = Workload::build(&cfg);
    let without = run_named(&cfg, &wl, "sccache");
    let with = run_named(&cfg, &wl, "pcr");
    assert!(with.prefetch_completed > 0);
    // Raw ssd_wait totals are not directly comparable across systems
    // (prefetch shifts *when* reads happen and changes residency); the
    // binding claim is the end effect: PCR's TTFT must not lose.
    assert!(
        with.report.ttft.mean <= without.report.ttft.mean * 1.001,
        "prefetching system must not lose on TTFT: {} vs {}",
        with.report.ttft.mean,
        without.report.ttft.mean
    );
}

#[test]
fn workload2_less_repetition_lower_hits() {
    let mut cfg1 = small_cfg(0.8);
    cfg1.oversample = true;
    let mut cfg2 = small_cfg(0.8);
    cfg2.oversample = false;
    cfg2.n_inputs = cfg2.n_requests; // W2: every input distinct
    let w1 = Workload::build(&cfg1);
    let w2 = Workload::build(&cfg2);
    assert!(w1.repetition_ratio > w2.repetition_ratio);
    let o1 = run_named(&cfg1, &w1, "pcr");
    let o2 = run_named(&cfg2, &w2, "pcr");
    assert!(
        o1.cache.hit_ratio() > o2.cache.hit_ratio(),
        "more repetition must produce more hits: {} vs {}",
        o1.cache.hit_ratio(),
        o2.cache.hit_ratio()
    );
}

#[test]
fn saturation_behaviour_at_extreme_rate() {
    // far beyond capacity the queue must grow and TTFT blow up — the
    // paper's Table 1 shows 100x TTFTs at 1 req/s for the big models
    let lo = {
        let cfg = small_cfg(0.2);
        let wl = Workload::build(&cfg);
        run_named(&cfg, &wl, "pcr").report.ttft.mean
    };
    let hi = {
        let cfg = small_cfg(30.0);
        let wl = Workload::build(&cfg);
        run_named(&cfg, &wl, "pcr").report.ttft.mean
    };
    assert!(hi > 3.0 * lo, "saturation must dominate: lo={lo} hi={hi}");
}

#[test]
fn virtual_duration_bounded_by_arrivals_plus_service() {
    let cfg = small_cfg(0.8);
    let wl = Workload::build(&cfg);
    let out = run_named(&cfg, &wl, "pcr");
    let last_arrival = wl.items.last().unwrap().arrival;
    assert!(out.virtual_duration >= last_arrival);
    // and not absurdly beyond (every request < 60s of service here)
    assert!(out.virtual_duration < last_arrival + 60.0 * wl.len() as f64);
}
