//! Declarative CLI flag parser (no `clap` offline). Supports
//! `--flag value`, `--flag=value`, boolean `--flag`, repeated flags,
//! positional arguments, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String),
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) => write!(f, "flag --{n} expects a value"),
            CliError::Invalid(n, v) => write!(f, "invalid value for --{n}: {v}"),
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// A small argument parser: declare flags, then `parse`.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
        }
    }

    /// Flag with a value and a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// Flag with a value, no default (optional).
    pub fn opt_no_default(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: None,
        });
        self
    }

    /// Boolean switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.program, self.about);
        for f in &self.flags {
            let arg = if f.takes_value {
                format!("--{} <v>", f.name)
            } else {
                format!("--{}", f.name)
            };
            let def = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<24} {}{def}\n", f.help));
        }
        s.push_str("  --help                   print this help\n");
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help);
            }
            if let Some(raw) = tok.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (raw.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                let value = if spec.takes_value {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    }
                } else {
                    "true".to_string()
                };
                args.values.entry(name).or_default().push(value);
            } else {
                args.positional.push(tok.clone());
            }
        }
        // fill defaults
        for f in &self.flags {
            if !args.values.contains_key(&f.name) {
                if let Some(d) = &f.default {
                    args.values
                        .insert(f.name.clone(), vec![d.clone()]);
                }
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args` (skipping argv[0]); print help and exit on
    /// `--help`, print error and exit non-zero on failure.
    pub fn parse_or_exit(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(CliError::Help) => {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse()
            .map_err(|_| CliError::Invalid(name.to_string(), raw.to_string()))
    }

    pub fn usize_of(&self, name: &str) -> usize {
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn f64_of(&self, name: &str) -> f64 {
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rate", "0.5", "request rate")
            .opt_no_default("model", "model name")
            .switch("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("rate"), Some("0.5"));
        assert_eq!(a.get("model"), None);
        assert!(!a.flag("verbose"));

        let a = cli()
            .parse(&argv(&["--rate", "1.0", "--verbose", "--model=llama"]))
            .unwrap();
        assert_eq!(a.f64_of("rate"), 1.0);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("model"), Some("llama"));
    }

    #[test]
    fn repeated_and_positional() {
        let a = cli()
            .parse(&argv(&["--model", "a", "--model", "b", "pos1", "pos2"]))
            .unwrap();
        assert_eq!(a.get_all("model"), vec!["a", "b"]);
        assert_eq!(a.get("model"), Some("b"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn errors() {
        assert!(matches!(
            cli().parse(&argv(&["--bogus"])),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            cli().parse(&argv(&["--model"])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(
            cli().parse(&argv(&["--help"])),
            Err(CliError::Help)
        ));
    }

    #[test]
    fn usage_mentions_flags() {
        let u = cli().usage();
        assert!(u.contains("--rate"));
        assert!(u.contains("default: 0.5"));
    }
}
