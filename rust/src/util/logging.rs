//! Tiny leveled logger (no `log`/`env_logger` runtime wiring needed):
//! level from `PCR_LOG` (error|warn|info|debug|trace), timestamped to
//! stderr, usable from any thread.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let lvl = std::env::var("PCR_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Warn) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, CLI `--log`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed();
    eprintln!(
        "[{:>10.4}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn set_level_gates() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Warn); // restore default-ish
    }
}
