//! Fixed-size worker pool over std threads + channels (no tokio in the
//! offline crate set). Used by the prefetcher (dedicated I/O workers,
//! matching the paper's "dedicated thread" design), the HTTP server, and
//! the e2e example's background SSD write-back.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing queued closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
    done: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let done = Arc::clone(&done);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                done.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            queued,
            done,
        }
    }

    /// Enqueue a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst) - self.done.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yields) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A one-shot future-like cell for retrieving a worker's result.
pub struct Promise<T> {
    rx: Receiver<T>,
}

impl<T: Send + 'static> Promise<T> {
    /// Run `f` on the pool, returning a promise for its result.
    pub fn spawn<F>(pool: &ThreadPool, f: F) -> Promise<T>
    where
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        pool.submit(move || {
            let _ = tx.send(f());
        });
        Promise { rx }
    }

    /// Block until the result is available.
    pub fn wait(self) -> T {
        self.rx.recv().expect("worker panicked")
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn promise_returns_value() {
        let pool = ThreadPool::new(2, "t");
        let p = Promise::spawn(&pool, || 6 * 7);
        assert_eq!(p.wait(), 42);
    }

    #[test]
    fn promises_in_flight_order_independent() {
        let pool = ThreadPool::new(2, "t");
        let ps: Vec<Promise<usize>> = (0..10)
            .map(|i| Promise::spawn(&pool, move || i * i))
            .collect();
        let got: Vec<usize> = ps.into_iter().map(|p| p.wait()).collect();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2, "t");
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
