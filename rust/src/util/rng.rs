//! Deterministic pseudo-random numbers and the distributions the serving
//! simulator needs: uniform, exponential (Poisson arrivals), Zipf
//! (document popularity) and normal (length jitter).
//!
//! No `rand` crate is available offline, so this is a self-contained
//! SplitMix64 + xoshiro256** implementation. Everything in the repo that
//! needs randomness takes an explicit seed — a whole experiment replays
//! bit-for-bit from its config.

/// SplitMix64: used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (seeded via SplitMix64 per the xoshiro authors' advice).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid; splitmix of any seed can't produce
        // four zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent substream (e.g. one per request).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson
    /// inter-arrival gaps, as in the paper's workload setup.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over ranks 1..=n via inverse-CDF on a precomputed
/// table. Document popularity in RAG corpora is heavily skewed; the
/// repetition ratios in the paper's workloads (40% / 35%) fall out of
/// the exponent + corpus size (see `rag::corpus`).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in [0, n) (0 = most popular).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [0usize; 7];
        for _ in 0..70_000 {
            seen[r.below(7) as usize] += 1;
        }
        for count in seen {
            // each bucket ~10k; loose 3-sigma-ish bound
            assert!((9_000..11_000).contains(&count), "count={count}");
        }
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(5);
        let lambda = 0.8;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.0);
        let mut r = Rng::new(7);
        let mut head = 0;
        for _ in 0..10_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // top-1% of ranks should get a large share under s=1.0
        assert!(head > 3_000, "head={head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let x = r.range(5, 8);
            assert!((5..=8).contains(&x));
            lo_seen |= x == 5;
            hi_seen |= x == 8;
        }
        assert!(lo_seen && hi_seen);
    }
}
