//! Latency statistics: the TTFT/E2EL/ITL summaries the paper reports
//! (mean, P50/P75/P90/P95/P99) plus streaming moments and histograms.

/// Accumulates raw samples; percentile queries sort lazily.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend_from(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = (q / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    /// The paper's reporting tuple.
    pub fn summary(&mut self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p75: self.percentile(75.0),
            p90: self.percentile(90.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            max: self.max(),
        }
    }
}

/// mean/P50/P75/P90/P95/P99/max of one metric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn row(&self, unit_scale: f64) -> String {
        format!(
            "n={:<6} mean={:>9.3} p50={:>9.3} p75={:>9.3} p90={:>9.3} p95={:>9.3} p99={:>9.3} max={:>9.3}",
            self.n,
            self.mean * unit_scale,
            self.p50 * unit_scale,
            self.p75 * unit_scale,
            self.p90 * unit_scale,
            self.p95 * unit_scale,
            self.p99 * unit_scale,
            self.max * unit_scale,
        )
    }
}

/// Streaming mean/variance (Welford) for counters that never need
/// percentiles — cheap to keep per cache-tier / per stream.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

/// Fixed-bucket histogram (log or linear) for ITL jitter plots.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
}

impl Histogram {
    /// `bounds` are ascending upper edges; one overflow bucket is added.
    pub fn new(bounds: Vec<f64>) -> Self {
        let n = bounds.len();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
        }
    }

    pub fn exponential(lo: f64, factor: f64, buckets: usize) -> Self {
        let mut bounds = Vec::with_capacity(buckets);
        let mut edge = lo;
        for _ in 0..buckets {
            bounds.push(edge);
            edge *= factor;
        }
        Histogram::new(bounds)
    }

    pub fn push(&mut self, x: f64) {
        let i = self
            .bounds
            .iter()
            .position(|b| x <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[i] += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sequence() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Samples::new();
        s.push(7.0);
        assert_eq!(s.percentile(50.0), 7.0);
        assert_eq!(s.percentile(99.0), 7.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn empty_min_max_are_nan_not_inf() {
        // regression: min/max used to fold from ±inf on empty sets,
        // leaking "inf" into pretty reports and bench JSON
        let s = Samples::new();
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
        assert!(s.clone().summary().max.is_nan());
        let mut s = s;
        s.push(2.0);
        s.push(-1.0);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 2.0);
    }

    #[test]
    fn summary_ordering() {
        let mut s = Samples::new();
        let mut seed = 11u64;
        for _ in 0..5000 {
            s.push((crate::util::rng::splitmix64(&mut seed) % 1000) as f64);
        }
        let sum = s.summary();
        assert!(sum.p50 <= sum.p75 && sum.p75 <= sum.p90);
        assert!(sum.p90 <= sum.p95 && sum.p95 <= sum.p99);
        assert!(sum.p99 <= sum.max);
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 4.0, 9.0, 16.0, 25.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(vec![1.0, 10.0, 100.0]);
        for x in [0.5, 5.0, 50.0, 500.0, 0.9, 100.0] {
            h.push(x);
        }
        assert_eq!(h.counts(), &[2, 1, 2, 1]);
        assert_eq!(h.total(), 6);
    }

    #[test]
    fn extend_from_merges() {
        let mut a = Samples::new();
        a.push(1.0);
        let mut b = Samples::new();
        b.push(3.0);
        a.extend_from(&b);
        assert_eq!(a.len(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }
}
