//! Shared substrates: PRNG + distributions, JSON, statistics, CLI
//! parsing, logging, a thread pool, and a mini property-testing harness.
//! Everything is hand-rolled because the build is fully offline (see
//! DESIGN.md §System-inventory).

pub mod cli;
pub mod fxhash;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Format a byte count for humans (1536 -> "1.5 KiB").
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Format seconds for humans (0.0123 -> "12.3 ms").
pub fn fmt_secs(s: f64) -> String {
    if s.is_nan() {
        "nan".to_string()
    } else if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert!(fmt_secs(0.0123).contains("ms"));
        assert!(fmt_secs(2.5).contains("s"));
        assert!(fmt_secs(1e-7).contains("ns"));
    }
}
