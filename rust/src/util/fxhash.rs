//! Deterministic hashing (FxHash, the rustc algorithm).
//!
//! `std::collections::HashMap`'s default `RandomState` seeds per
//! instance, which makes *iteration order* vary across runs — and the
//! cache engine's eviction-candidate scans iterate maps, so experiments
//! would stop replaying bit-for-bit from their seeds. Every map in the
//! hot path uses these aliases instead.

use std::hash::{BuildHasherDefault, Hasher};

/// FxHash: multiply-xor word hasher (fast, deterministic, non-DoS-safe
/// — fine for internal keys that are already hashes).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a: FxHashMap<u64, u32> = FxHashMap::default();
        let mut b: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u64 {
            a.insert(i.wrapping_mul(0x9E3779B97F4A7C15), i as u32);
            b.insert(i.wrapping_mul(0x9E3779B97F4A7C15), i as u32);
        }
        let ka: Vec<u64> = a.keys().copied().collect();
        let kb: Vec<u64> = b.keys().copied().collect();
        assert_eq!(ka, kb, "iteration order must be reproducible");
    }

    #[test]
    fn hashes_spread() {
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let h = |x: u64| {
            let mut s = bh.build_hasher();
            x.hash(&mut s);
            s.finish()
        };
        // consecutive keys should not collide in low bits
        let mut low: std::collections::HashSet<u64> = Default::default();
        for i in 0..256u64 {
            low.insert(h(i) & 0xFF);
        }
        assert!(low.len() > 100, "low-bit spread too poor: {}", low.len());
    }
}
