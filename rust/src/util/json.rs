//! Minimal JSON parser/serializer.
//!
//! The offline crate set has no `serde_json`, so PCR carries its own
//! small implementation. It covers everything the repo exchanges as
//! JSON: the AOT `manifest.json`, metric dumps from benches, and the
//! HTTP server's request/response bodies. UTF-16 surrogate escapes and
//! exotic number formats are supported to the extent the grammar
//! requires; this is a strict parser (no comments, no trailing commas).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — experiment outputs diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    // ----- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
        self
    }

    // ----- parsing ---------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialization ---------------------------------------------------
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.bytes.get(self.pos + 1) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2) == Some(&b'u')
                                {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            self.pos += 1;
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"),
                   Some(&Json::Bool(false)));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"model":{"n_layers":4,"theta":10000.5},"arr":[1,"two",null,true]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn real_manifest_parses() {
        // The actual artifact manifest, if present (depends on `make
        // artifacts` having run — skip silently otherwise).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("model").is_some());
            assert!(m.get("params").unwrap().as_arr().unwrap().len() > 3);
        }
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("x", 1u64.into()).set("y", "z".into());
        assert_eq!(o.dump(), r#"{"x":1,"y":"z"}"#);
    }
}
