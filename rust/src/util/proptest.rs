//! Mini property-testing harness (no `proptest` crate offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs; on failure it performs greedy shrinking via the input's
//! `Shrink` implementation and panics with the minimal counterexample.
//! Coordinator invariants (prefix-tree shape, leaf-only eviction, LRU
//! order, residency accounting, scheduler plans) are all checked through
//! this harness — see the `cache` and `serve` test modules.

use crate::util::rng::Rng;
use std::fmt::Debug;

/// Types that can propose strictly-smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate shrinks, in decreasing-aggressiveness order.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        (*self as u64).shrinks().into_iter().map(|x| x as usize).collect()
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // remove halves, then single elements, then shrink one element
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for s in self[i].shrinks() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` on `cases` inputs drawn by `gen`; shrink on failure.
///
/// Panics with the minimal failing input so `cargo test` reports it.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Clone + Debug + Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg, steps) = shrink_loop(input, msg, &mut prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}, {steps} shrink steps)\n\
                 minimal input: {min_input:?}\nfailure: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut msg: String, prop: &mut P) -> (T, String, usize)
where
    T: Clone + Debug + Shrink,
    P: FnMut(&T) -> PropResult,
{
    let mut steps = 0;
    'outer: loop {
        for cand in input.shrinks() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                steps += 1;
                if steps > 10_000 {
                    break 'outer;
                }
                continue 'outer;
            }
        }
        break;
    }
    (input, msg, steps)
}

/// Convenience: turn a bool into a PropResult with a message.
pub fn check(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |rng| rng.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "minimal input: 10")]
    fn shrinks_to_minimal_counterexample() {
        // property: x < 10. Minimal failure is exactly 10.
        forall(
            2,
            200,
            |rng| rng.below(1000),
            |x| check(*x < 10, format!("{x} >= 10")),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn vec_shrinking_reduces_length() {
        forall(
            3,
            50,
            |rng| {
                let n = rng.below(20) as usize;
                (0..n).map(|_| rng.below(50)).collect::<Vec<u64>>()
            },
            |v| check(v.len() < 3, "long vec"),
        );
    }

    #[test]
    fn u64_shrinks_monotone() {
        for s in 17u64.shrinks() {
            assert!(s < 17);
        }
        assert!(0u64.shrinks().is_empty());
    }
}
