//! The real-path serving executor: PJRT model + cache engine + chunk
//! byte stores, glued into the same prefill-with-reuse flow the
//! simulator models. Used by `examples/e2e_serving.rs` and the HTTP
//! server; every latency here is *wall clock*, not simulated.

use crate::cache::chunk::ChunkedSeq;
use crate::cache::engine::{CacheConfig, CacheEngine};
use crate::cache::policy::registry as policy_registry;
use crate::cache::store::{ChunkStore, FileStore, MemStore, StoreStats};
use crate::cache::tier::Tier;
use crate::io::{FetchSource, IoConfig, IoStats, Lane, TransferEngine};
use crate::runtime::client::{PjrtModel, PrefillOut};
use crate::runtime::kv;
use crate::runtime::manifest::Manifest;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// Result of serving one request on the real model.
#[derive(Debug)]
pub struct ServeResult {
    /// argmax of the last-token logits (the "first generated token").
    pub first_token: u32,
    pub logits: Vec<f32>,
    /// Wall seconds spent in prefill (the real TTFT component).
    pub prefill_seconds: f64,
    pub reused_tokens: usize,
    pub computed_tokens: usize,
    pub reused_from_dram: usize,
    pub reused_from_ssd: usize,
    /// Prefill passes used (long inputs run multiple bucket passes).
    pub passes: usize,
}

/// How long a demand fetch may wait on the transfer engine before the
/// executor falls back to a direct read. Generous: it only fires if
/// the disk stalls or a submit was rejected under backpressure.
const DEMAND_FETCH_TIMEOUT: Duration = Duration::from_secs(30);

/// Real-model executor with a DRAM (mem) + SSD (spill-dir) chunk cache.
/// SSD↔DRAM byte movement goes through the asynchronous
/// [`TransferEngine`]: demand fetches are submitted up front and run on
/// the engine's workers (overlapping each other and any in-flight
/// prefetch), and [`PjrtExecutor::prefetch_chain`] warms upcoming
/// requests on the prefetch lane without ever delaying demand reads.
pub struct PjrtExecutor {
    pub model: PjrtModel,
    pub cache: CacheEngine,
    dram: MemStore,
    // Declared before `ssd`: drop order shuts the engine's workers down
    // before the FileStore's Drop sweeps the spill files they read.
    io: Option<TransferEngine>,
    ssd: Option<Arc<RwLock<FileStore>>>,
    pub chunk_tokens: usize,
}

impl PjrtExecutor {
    /// `dram_chunks`/`ssd_chunks` size the tiers in whole chunks.
    /// `spill_dir = None` disables the SSD tier. `policy` is an
    /// eviction-policy registry name (empty = `lookahead-lru`).
    pub fn new(
        manifest: Manifest,
        dram_chunks: u64,
        ssd_chunks: u64,
        spill_dir: Option<&Path>,
        policy: &str,
    ) -> Result<PjrtExecutor> {
        Self::with_io(
            manifest,
            dram_chunks,
            ssd_chunks,
            spill_dir,
            policy,
            IoConfig::default(),
        )
    }

    /// Like [`PjrtExecutor::new`] with explicit transfer-engine sizing
    /// (the `[io]` config section: worker count and lane depths).
    pub fn with_io(
        manifest: Manifest,
        dram_chunks: u64,
        ssd_chunks: u64,
        spill_dir: Option<&Path>,
        policy: &str,
        io_cfg: IoConfig,
    ) -> Result<PjrtExecutor> {
        let chunk_tokens = manifest.chunk_tokens;
        let dims = manifest.kv_dims();
        let chunk_bytes = dims.chunk_bytes(chunk_tokens) as u64;
        let policy = if policy.is_empty() { "lookahead-lru" } else { policy };
        anyhow::ensure!(
            policy_registry::parse(policy).is_some(),
            "unknown eviction policy '{}' (registered: {})",
            policy,
            policy_registry::names_joined()
        );
        let model = PjrtModel::load(manifest)?;
        let ssd = match spill_dir {
            Some(dir) if ssd_chunks > 0 => {
                Some(Arc::new(RwLock::new(FileStore::new(dir)?)))
            }
            _ => None,
        };
        // The engine reads through the RwLock'd store, so worker fetches
        // proceed concurrently with each other; writes (put/delete on
        // this thread) take the write lock.
        let io = ssd
            .as_ref()
            .map(|s| TransferEngine::new(io_cfg, s.clone() as Arc<dyn FetchSource>));
        let cache = CacheEngine::new(CacheConfig {
            chunk_tokens,
            gpu_capacity: 0, // the CPU PJRT device has no separate HBM tier
            dram_capacity: dram_chunks * chunk_bytes,
            ssd_capacity: if ssd.is_some() { ssd_chunks * chunk_bytes } else { 0 },
            policy: policy.to_string(),
        });
        Ok(PjrtExecutor {
            model,
            cache,
            dram: MemStore::new(),
            io,
            ssd,
            chunk_tokens,
        })
    }

    /// Serve one request: match the prefix, assemble reused KV, run as
    /// many prefill passes as the buckets require, store new chunks.
    pub fn serve(&mut self, tokens: &[u32]) -> Result<ServeResult> {
        let t0 = Instant::now();
        // Land any prefetch completions first: chunks the engine already
        // pulled off SSD promote into DRAM before the prefix lookup, so
        // a warmed chunk is a DRAM hit rather than a demand read.
        self.drain_io();
        let dims = self.model.kv_dims();
        let chunk = self.chunk_tokens;
        let (max_p, max_n) = self.model.manifest.max_bucket();
        anyhow::ensure!(
            tokens.len() <= max_p + max_n,
            "input of {} tokens exceeds the real model's {} context",
            tokens.len(),
            max_p + max_n
        );
        anyhow::ensure!(!tokens.is_empty(), "empty input");

        let chain = ChunkedSeq::new(tokens, chunk);
        let lookup = self.cache.lookup(&chain.keys);
        // Reuse is capped by the largest past bucket.
        let mut reuse_chunks = lookup.nodes.len().min(max_p / chunk);
        // Ensure the remaining computation fits the new bucket (possibly
        // via multiple passes — each pass's past must also fit).
        while tokens.len() - reuse_chunks * chunk > max_n
            && (reuse_chunks + 1) * chunk <= max_p
            && reuse_chunks < lookup.nodes.len()
        {
            reuse_chunks += 1; // shouldn't trigger given the cap above
        }
        let mut from_dram = 0;
        let mut from_ssd = 0;

        // Fetch reused chunk blobs. Demand reads are submitted to the
        // transfer engine up front — they run on its workers (demand
        // lane, preempting queued prefetch work; an in-flight prefetch
        // of the same key is *upgraded*, so the chunk is read once) —
        // then collected in order. This thread never touches the disk
        // itself unless the engine rejects or times out.
        if let Some(io) = &self.io {
            // A paused engine (test/demo staging) must not deadlock a
            // demand fetch.
            io.resume();
            for i in 0..reuse_chunks {
                let key = chain.keys[i];
                if !self.dram.contains(key) {
                    io.submit(key, Lane::Demand);
                }
            }
        }
        let mut blobs: Vec<Vec<u8>> = Vec::with_capacity(reuse_chunks);
        for i in 0..reuse_chunks {
            let key = chain.keys[i];
            let blob = if let Some(b) = self.dram.get(key)? {
                from_dram += 1;
                b
            } else if let Some(ssd) = &self.ssd {
                let fetched = self
                    .io
                    .as_ref()
                    .and_then(|io| io.take_blocking(key, DEMAND_FETCH_TIMEOUT))
                    .map(|c| c.data)
                    // engine rejected the submit (backpressure) or timed
                    // out: direct read keeps the request correct
                    .unwrap_or_else(|| ssd.read().unwrap().get(key).and_then(|b| {
                        b.ok_or_else(|| anyhow!("chunk {:016x} missing from source", key.0))
                    }));
                let b = fetched
                    .map_err(|e| anyhow!("demand fetch of chunk {:016x}: {e}", key.0))?;
                from_ssd += 1;
                // promote into DRAM (metadata + bytes)
                let id = self.cache.tree.get(key).unwrap();
                if self.cache.promote(id, Tier::Dram) {
                    self.dram.put(key, &b)?;
                }
                b
            } else {
                return Err(anyhow!("chunk resident but no store holds it"));
            };
            blobs.push(blob);
        }

        let mut past_tokens = reuse_chunks * chunk;
        let mut computed = 0usize;
        let mut remaining: &[u32] = &tokens[past_tokens..];
        let mut last: Option<PrefillOut> = None;
        let mut all_new: Vec<(usize, Vec<f32>, Vec<f32>, usize)> = Vec::new(); // (start_tok, k, v, valid)
        let mut passes = 0;

        while !remaining.is_empty() {
            let new_len = remaining.len().min(max_n);
            let bucket = self
                .model
                .manifest
                .pick_prefill_bucket(past_tokens, new_len)
                .ok_or_else(|| anyhow!("no bucket for past={past_tokens} new={new_len}"))?;
            let (bp, bn) = bucket;
            let (past_k, past_v) = kv::assemble_past(&blobs, dims, bp, chunk);
            let mut toks: Vec<i32> = remaining[..new_len].iter().map(|t| *t as i32).collect();
            toks.resize(bn, 0);
            let out = self
                .model
                .prefill(bucket, &past_k, &past_v, &toks, past_tokens, new_len)?;
            passes += 1;

            // chunk the new KV and extend the reused-prefix blobs so the
            // next pass sees them as past
            let new_blobs = kv::chunks_from_new_kv(
                &out.new_k, &out.new_v, dims, bn, new_len, chunk);
            all_new.push((past_tokens, out.new_k.clone(), out.new_v.clone(), new_len));
            blobs.extend(new_blobs);

            past_tokens += new_len;
            computed += new_len;
            remaining = &remaining[new_len..];
            last = Some(out);
        }

        // Store the newly computed full chunks (DRAM + SSD write-back).
        let chunk_bytes = dims.chunk_bytes(chunk) as u64;
        let full_chunks = tokens.len() / chunk;
        let mut parent = reuse_chunks
            .checked_sub(1)
            .map(|i| self.cache.tree.get(chain.keys[i]).unwrap());
        for i in reuse_chunks..full_chunks {
            let key = chain.keys[i];
            let blob = &blobs[i];
            let dram_id = self.cache.insert(parent, key, chunk_bytes, Tier::Dram);
            if dram_id.is_some() {
                self.dram.put(key, blob)?;
            }
            let mut id = dram_id;
            if let Some(ssd) = &self.ssd {
                let ssd_id = self.cache.insert(parent, key, chunk_bytes, Tier::Ssd);
                if ssd_id.is_some() {
                    ssd.write().unwrap().put(key, blob)?;
                }
                id = id.or(ssd_id);
            }
            match id {
                Some(id) => parent = Some(id),
                None => break,
            }
        }
        self.sync_stores();

        let out = last.expect("at least one pass");
        let first_token = argmax(&out.logits);
        Ok(ServeResult {
            first_token,
            logits: out.logits,
            prefill_seconds: t0.elapsed().as_secs_f64(),
            reused_tokens: reuse_chunks * chunk,
            computed_tokens: computed,
            reused_from_dram: from_dram,
            reused_from_ssd: from_ssd,
            passes,
        })
    }

    /// Submit prefetch-lane loads for every chunk of `chain` whose
    /// metadata says SSD-resident but whose bytes are not in DRAM yet —
    /// the real-path analogue of the simulator's queue-window prefetch.
    /// Returns the number of accepted submissions (in-flight duplicates
    /// dedup, full queues reject; both only show up in the counters).
    pub fn prefetch_chain(&mut self, chain: &ChunkedSeq) -> usize {
        let Some(io) = &self.io else { return 0 };
        let mut n = 0;
        for key in &chain.keys {
            let Some(id) = self.cache.tree.get(*key) else { continue };
            let tiers = self.cache.tree.node(id).tiers;
            if !tiers.contains(Tier::Ssd) || tiers.contains(Tier::Dram) {
                continue;
            }
            if self.dram.contains(*key) {
                continue;
            }
            if io.submit(*key, Lane::Prefetch).accepted() {
                n += 1;
            }
        }
        n
    }

    /// Promote completed engine reads into DRAM (metadata + bytes).
    /// Called at the top of every `serve`; cheap no-op when idle.
    pub fn drain_io(&mut self) {
        let Some(io) = &self.io else { return };
        for c in io.drain() {
            let Ok(data) = c.data else { continue }; // failures are counted by the engine
            let Some(id) = self.cache.tree.get(c.key) else { continue };
            let tiers = self.cache.tree.node(id).tiers;
            if !tiers.contains(Tier::Ssd) || tiers.contains(Tier::Dram) {
                continue; // evicted or already promoted since submission
            }
            if self.cache.promote(id, Tier::Dram) {
                let _ = self.dram.put(c.key, &data);
            }
        }
    }

    /// Pause the engine's workers (deterministic staging for tests and
    /// the e2e upgrade demo). `serve` resumes automatically.
    pub fn io_pause(&self) {
        if let Some(io) = &self.io {
            io.pause();
        }
    }

    /// Lane counters of the transfer engine (`None` without an SSD tier).
    pub fn io_stats(&self) -> Option<IoStats> {
        self.io.as_ref().map(|io| io.stats())
    }

    /// Keep spill files on shutdown so a restarted process reconciles
    /// them instead of re-spilling from cold (deployment mode). Off by
    /// default: tests and one-shot runs sweep their spill dirs.
    pub fn set_spill_persist(&mut self, persist: bool) {
        if let Some(ssd) = &self.ssd {
            ssd.write().unwrap().set_persist(persist);
        }
    }

    /// Spill-store error counters — fsync/delete failures, checksum
    /// quarantines, vanished files (`None` without an SSD tier). The
    /// handle is shared: it stays live across later puts/gets.
    pub fn store_stats(&self) -> Option<StoreStats> {
        self.ssd.as_ref().map(|s| s.read().unwrap().stats())
    }

    /// Drop store bytes for chunks the metadata engine evicted.
    fn sync_stores(&mut self) {
        let dram_keys: Vec<_> = self
            .cache
            .tree
            .ids()
            .map(|id| (self.cache.tree.node(id).key, self.cache.tree.node(id).tiers))
            .collect();
        // Remove bytes whose metadata says "not resident in that tier".
        // (Store keys not in the tree at all were evicted + swept.)
        let mut dram_live: std::collections::HashSet<u64> = Default::default();
        let mut ssd_live: std::collections::HashSet<u64> = Default::default();
        for (key, tiers) in &dram_keys {
            if tiers.contains(Tier::Dram) {
                dram_live.insert(key.0);
            }
            if tiers.contains(Tier::Ssd) {
                ssd_live.insert(key.0);
            }
        }
        let stale_dram: Vec<_> = self
            .dram_keys()
            .into_iter()
            .filter(|k| !dram_live.contains(&k.0))
            .collect();
        for k in stale_dram {
            let _ = self.dram.delete(k);
        }
        if let Some(ssd) = &self.ssd {
            let stale: Vec<_> = ssd
                .read()
                .unwrap()
                .keys()
                .into_iter()
                .filter(|k| !ssd_live.contains(&k.0))
                .collect();
            let mut store = ssd.write().unwrap();
            for k in stale {
                // an in-flight read of an evicted chunk is pointless:
                // cancel it before it hits the disk
                if let Some(io) = &self.io {
                    io.cancel(k);
                }
                let _ = store.delete(k);
            }
        }
    }

    fn dram_keys(&self) -> Vec<crate::cache::chunk::ChunkKey> {
        // MemStore doesn't expose keys; track via the tree (cheap).
        self.cache
            .tree
            .ids()
            .map(|id| self.cache.tree.node(id).key)
            .filter(|k| self.dram.contains(*k))
            .collect()
    }
}

/// Cache statistics snapshot safe to ship across threads.
#[derive(Clone, Copy, Debug)]
pub struct ExecStats {
    pub cache: crate::cache::engine::CacheStats,
    pub vocab: usize,
    /// Transfer-engine lane counters (`None` without an SSD tier).
    pub io: Option<IoStats>,
    /// Total spill-store errors (fsync + delete + checksum + lost);
    /// feeds the `store_errors` degradation metric.
    pub store_errors: u64,
}

enum Job {
    Serve(Vec<u32>, std::sync::mpsc::Sender<Result<ServeResult>>),
    Stats(std::sync::mpsc::Sender<ExecStats>),
}

/// Thread-safe handle to a [`PjrtExecutor`] running on its own actor
/// thread. The `xla` crate's client is `Rc`-based (not `Send`), so the
/// executor is moved onto one dedicated thread and driven via a
/// channel — which is also the paper's regime: one LLM executor,
/// batching upstream.
pub struct ExecutorHandle {
    tx: std::sync::Mutex<std::sync::mpsc::Sender<Job>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ExecutorHandle {
    /// Build the executor on its own thread. `build` runs there, so
    /// the non-Send internals never cross threads.
    pub fn spawn<F>(build: F) -> Result<ExecutorHandle>
    where
        F: FnOnce() -> Result<PjrtExecutor> + Send + 'static,
    {
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                let mut exec = match build() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Serve(tokens, reply) => {
                            let _ = reply.send(exec.serve(&tokens));
                        }
                        Job::Stats(reply) => {
                            let _ = reply.send(ExecStats {
                                cache: exec.cache.stats,
                                vocab: exec.model.manifest.vocab,
                                io: exec.io_stats(),
                                store_errors: exec
                                    .store_stats()
                                    .map_or(0, |s| s.total()),
                            });
                        }
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow!("executor thread died"))??;
        Ok(ExecutorHandle {
            tx: std::sync::Mutex::new(tx),
            thread: Some(thread),
        })
    }

    pub fn serve(&self, tokens: Vec<u32>) -> Result<ServeResult> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Serve(tokens, reply_tx))
            .map_err(|_| anyhow!("executor gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    pub fn stats(&self) -> Result<ExecStats> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Stats(reply_tx))
            .map_err(|_| anyhow!("executor gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("executor gone"))
    }
}

impl Drop for ExecutorHandle {
    fn drop(&mut self) {
        // close the channel, then join the actor
        {
            let (tx, _) = std::sync::mpsc::channel();
            *self.tx.lock().unwrap() = tx;
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_artifacts_dir;

    /// Real-model integration tests only run when artifacts exist.
    /// `tag` keeps spill dirs disjoint: FileStore adopts existing files
    /// on open, so parallel tests must not share a directory.
    fn executor(dram_chunks: u64, tag: &str) -> Option<PjrtExecutor> {
        let manifest = Manifest::load(default_artifacts_dir()).ok()?;
        let dir = std::env::temp_dir().join(format!("pcr-exec-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Some(PjrtExecutor::new(manifest, dram_chunks, 64, Some(&dir), "").unwrap())
    }

    fn input(seed: u64, len: usize) -> Vec<u32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..len).map(|_| rng.below(2048) as u32).collect()
    }

    #[test]
    fn serve_then_reuse_matches_cold_logits() {
        let Some(mut ex) = executor(64, "reuse") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let toks = input(1, 300); // 2 full chunks + tail of 44
        let cold = ex.serve(&toks).unwrap();
        assert_eq!(cold.reused_tokens, 0);
        assert!(cold.computed_tokens == 300);
        let warm = ex.serve(&toks).unwrap();
        assert_eq!(warm.reused_tokens, 256);
        assert_eq!(warm.computed_tokens, 44);
        assert!(warm.reused_from_dram > 0);
        // The paper's losslessness claim, end-to-end through PJRT:
        // reused-prefix logits match cold logits.
        let max_diff = cold
            .logits
            .iter()
            .zip(&warm.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "reuse changed logits by {max_diff}");
        assert_eq!(cold.first_token, warm.first_token);
    }

    #[test]
    fn shared_prefix_partial_reuse() {
        let Some(mut ex) = executor(64, "shared") else { return };
        let mut a = input(2, 256);
        let mut b = a.clone();
        a.extend(input(3, 100));
        b.extend(input(4, 100));
        let _ = ex.serve(&a).unwrap();
        let rb = ex.serve(&b).unwrap();
        assert_eq!(rb.reused_tokens, 256); // shares exactly the 2-chunk prefix
    }

    #[test]
    fn long_input_multi_pass() {
        let Some(mut ex) = executor(64, "multipass") else { return };
        let toks = input(5, 900);
        let r = ex.serve(&toks).unwrap();
        assert!(r.passes >= 2, "900 fresh tokens need 2 passes, got {}", r.passes);
        assert_eq!(r.computed_tokens, 900);
        // serve again: reuse capped by the max past bucket (512)
        let r2 = ex.serve(&toks).unwrap();
        assert_eq!(r2.reused_tokens, 512);
    }

    #[test]
    fn rejects_oversized_input() {
        let Some(mut ex) = executor(8, "oversized") else { return };
        let toks = input(6, 2000);
        assert!(ex.serve(&toks).is_err());
    }

    #[test]
    fn demand_reads_and_prefetch_upgrades_go_through_the_engine() {
        // tiny DRAM (2 chunks) so a second request pushes the first
        // one's chunks to SSD-only
        let Some(mut ex) = executor(2, "io") else { return };
        let toks = input(7, 700);
        let other = input(8, 700);
        let r1 = ex.serve(&toks).unwrap();
        assert_eq!(r1.reused_tokens, 0);
        let _ = ex.serve(&other).unwrap(); // evicts `toks` chunks from DRAM
        let r2 = ex.serve(&toks).unwrap();
        let io = ex.io_stats().unwrap();
        if r2.reused_from_ssd > 0 {
            assert!(
                io.demand.submitted > 0,
                "SSD demand reads must go through the engine"
            );
            assert_eq!(io.demand.failed, 0);
        }
        // stage prefetches for the now-SSD-resident `other` chain while
        // the engine is paused; the next serve's demand submits must
        // upgrade them (read once, at demand priority)
        let _ = ex.serve(&toks).unwrap(); // make `other` SSD-only again
        ex.io_pause();
        let chain = ChunkedSeq::new(&other, ex.chunk_tokens);
        let staged = ex.prefetch_chain(&chain);
        let before = ex.io_stats().unwrap().upgraded;
        let r3 = ex.serve(&other).unwrap(); // resumes the engine itself
        if staged > 0 && r3.reused_from_ssd > 0 {
            let after = ex.io_stats().unwrap().upgraded;
            assert!(after > before, "queued prefetches must be upgraded, not re-read");
        }
    }
}
