//! PJRT runtime: loads the AOT HLO-text artifacts, keeps the weights
//! resident as device buffers, and runs prefill/decode natively. This
//! is the L2 model on the rust request path — Python is long gone by
//! the time this code runs.
//!
//! Executables are compiled lazily per shape bucket and cached; weights
//! are uploaded once (`execute_b` with persistent `PjRtBuffer`s), so a
//! steady-state prefill costs one H2D copy for the past KV + tokens and
//! one D2H for the outputs — the real-machine analogue of the paper's
//! CPU↔GPU KV traffic.

use crate::runtime::kv::KvDims;
use crate::runtime::manifest::Manifest;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

// Default build: the in-crate stub (fails at client creation with a
// clear message). `--features pjrt` resolves `xla::` against the real
// xla-rs crate instead (which must then be added as a dependency).
#[cfg(not(feature = "pjrt"))]
use crate::runtime::xla_shim as xla;

/// Outputs of one prefill call.
#[derive(Debug)]
pub struct PrefillOut {
    /// `[vocab]` logits of the last valid token.
    pub logits: Vec<f32>,
    /// `[L, Hkv, N_bucket, D]` new K (garbage beyond `new_len`).
    pub new_k: Vec<f32>,
    pub new_v: Vec<f32>,
    /// The bucket that served the call.
    pub bucket: (usize, usize),
}

/// Outputs of one decode step.
#[derive(Debug)]
pub struct DecodeOut {
    pub logits: Vec<f32>,
    /// Updated padded caches `[L, Hkv, S_max, D]`.
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
}

/// The compiled model + resident weights.
pub struct PjrtModel {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    weights: Vec<xla::PjRtBuffer>,
    prefill_exes: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    decode_exe: Option<(usize, xla::PjRtLoadedExecutable)>,
}

impl PjrtModel {
    /// Create the CPU PJRT client and upload weights. Executables
    /// compile lazily on first use of each bucket.
    pub fn load(manifest: Manifest) -> Result<PjrtModel> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let host_weights = manifest.load_weights()?;
        let mut weights = Vec::with_capacity(host_weights.len());
        for (spec, data) in manifest.params.iter().zip(&host_weights) {
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
                .map_err(|e| anyhow!("uploading {}: {e:?}", spec.name))?;
            weights.push(buf);
        }
        Ok(PjrtModel {
            client,
            manifest,
            weights,
            prefill_exes: HashMap::new(),
            decode_exe: None,
        })
    }

    pub fn kv_dims(&self) -> KvDims {
        self.manifest.kv_dims()
    }

    fn compile(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
    }

    fn prefill_exe(&mut self, bucket: (usize, usize)) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.prefill_exes.contains_key(&bucket) {
            let path = self
                .manifest
                .prefill_file(bucket.0, bucket.1)
                .ok_or_else(|| anyhow!("no artifact for bucket {bucket:?}"))?;
            let exe = self.compile(&path)?;
            self.prefill_exes.insert(bucket, exe);
        }
        Ok(&self.prefill_exes[&bucket])
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("H2D f32 {dims:?}: {e:?}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow!("H2D i32 {dims:?}: {e:?}"))
    }

    /// Run one prefill: `past_k/past_v` are `[L, Hkv, P_bucket, D]`
    /// (zero-padded beyond `past_len`), `tokens` is padded to the
    /// bucket's N. Returns last-valid-token logits + the new KV.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill(
        &mut self,
        bucket: (usize, usize),
        past_k: &[f32],
        past_v: &[f32],
        tokens: &[i32],
        past_len: usize,
        new_len: usize,
    ) -> Result<PrefillOut> {
        let dims = self.kv_dims();
        let (p, n) = bucket;
        anyhow::ensure!(tokens.len() == n, "tokens not padded to bucket");
        anyhow::ensure!(past_k.len() == dims.elems(p), "past_k shape");
        anyhow::ensure!(past_len <= p && new_len >= 1 && new_len <= n, "lengths");

        // compile first (needs &mut self), then build the arg list
        self.prefill_exe(bucket)?;
        let kv_shape = [dims.n_layers, dims.n_kv_heads, p, dims.head_dim];
        let args: Vec<xla::PjRtBuffer> = vec![
            self.buf_f32(past_k, &kv_shape)?,
            self.buf_f32(past_v, &kv_shape)?,
            self.buf_i32(tokens, &[n])?,
            self.buf_i32(&[past_len as i32], &[])?,
            self.buf_i32(&[new_len as i32], &[])?,
        ];
        // ABI: [*weights, past_k, past_v, tokens, past_len, new_len]
        let all: Vec<&xla::PjRtBuffer> =
            self.weights.iter().chain(args.iter()).collect();
        let exe = &self.prefill_exes[&bucket];
        let result = exe
            .execute_b(&all)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("D2H: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
        let logits = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let new_k = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let new_v = parts[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(logits.len() == self.manifest.vocab, "logits shape");
        anyhow::ensure!(new_k.len() == dims.elems(n), "new_k shape");
        Ok(PrefillOut {
            logits,
            new_k,
            new_v,
            bucket,
        })
    }

    /// One decode step against padded caches `[L, Hkv, S_max, D]`.
    pub fn decode(
        &mut self,
        k_cache: &[f32],
        v_cache: &[f32],
        token: i32,
        cur_len: usize,
    ) -> Result<DecodeOut> {
        let dims = self.kv_dims();
        let (s_max, path) = self
            .manifest
            .decode_file()
            .ok_or_else(|| anyhow!("no decode artifact"))?;
        anyhow::ensure!(k_cache.len() == dims.elems(s_max), "k_cache shape");
        anyhow::ensure!(cur_len < s_max, "cache full");
        if self.decode_exe.is_none() {
            let exe = self.compile(&path)?;
            self.decode_exe = Some((s_max, exe));
        }
        let kv_shape = [dims.n_layers, dims.n_kv_heads, s_max, dims.head_dim];
        let args: Vec<xla::PjRtBuffer> = vec![
            self.buf_f32(k_cache, &kv_shape)?,
            self.buf_f32(v_cache, &kv_shape)?,
            self.buf_i32(&[token], &[])?,
            self.buf_i32(&[cur_len as i32], &[])?,
        ];
        let all: Vec<&xla::PjRtBuffer> =
            self.weights.iter().chain(args.iter()).collect();
        let exe = &self.decode_exe.as_ref().unwrap().1;
        let result = exe
            .execute_b(&all)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("D2H: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs");
        Ok(DecodeOut {
            logits: parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            k_cache: parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            v_cache: parts[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    /// Number of compiled executables (diagnostics).
    pub fn compiled_buckets(&self) -> usize {
        self.prefill_exes.len() + usize::from(self.decode_exe.is_some())
    }
}
