//! PJRT runtime: AOT artifact loading, KV byte marshaling, and the
//! real-model executor. HLO *text* is the interchange format (jax ≥0.5
//! emits 64-bit-id protos that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids — see DESIGN.md).

pub mod client;
pub mod executor;
pub mod kv;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod xla_shim;
