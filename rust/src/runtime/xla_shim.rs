//! Offline stub of the `xla` crate's PJRT surface.
//!
//! The real-model path (`runtime::client`) binds to the `xla` crate
//! (xla-rs) when the `pjrt` cargo feature is on. The default build has
//! no such dependency — this shim provides the same API shape with
//! every entry point failing at [`PjRtClient::cpu`], so the crate
//! compiles and the simulator/tests run everywhere, and the real-model
//! integration tests (which probe for artifacts first) skip cleanly.

use std::fmt;

/// Error type matching the call sites' `{e:?}` formatting.
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (add the xla-rs dependency and build with --features pjrt)"
            .into(),
    )
}

pub struct PjRtClient;
pub struct PjRtBuffer;
pub struct PjRtLoadedExecutable;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}
