//! KV-cache byte marshaling between the model's `[L, Hkv, T, D]` f32
//! row-major arrays (what the HLO returns) and per-chunk blobs (what
//! the cache tiers store).
//!
//! Chunk blob layout: `K[L, Hkv, chunk, D]` followed by `V[L, Hkv,
//! chunk, D]`, f32 little-endian — self-contained, so a chunk can be
//! spilled to disk and reassembled into any later prefill's `past_k /
//! past_v` buckets without touching its neighbours.

/// Geometry of one KV array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvDims {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl KvDims {
    /// f32 elements for `tokens` tokens of K (or V) alone.
    pub fn elems(&self, tokens: usize) -> usize {
        self.n_layers * self.n_kv_heads * tokens * self.head_dim
    }

    /// Blob bytes for one chunk (K + V).
    pub fn chunk_bytes(&self, chunk_tokens: usize) -> usize {
        2 * self.elems(chunk_tokens) * 4
    }
}

/// Slice tokens `[t0, t0+count)` out of a `[L, Hkv, T, D]` array.
///
/// Row-major strides: layer stride = Hkv·T·D, head stride = T·D, token
/// stride = D.
pub fn slice_tokens(src: &[f32], dims: KvDims, total_tokens: usize,
                    t0: usize, count: usize) -> Vec<f32> {
    assert!(t0 + count <= total_tokens, "slice out of range");
    assert_eq!(src.len(), dims.elems(total_tokens), "src shape mismatch");
    let d = dims.head_dim;
    let mut out = Vec::with_capacity(dims.elems(count));
    for l in 0..dims.n_layers {
        for h in 0..dims.n_kv_heads {
            let base = (l * dims.n_kv_heads + h) * total_tokens * d;
            out.extend_from_slice(&src[base + t0 * d..base + (t0 + count) * d]);
        }
    }
    out
}

/// Write tokens `[t0, t0+count)` of `dst` (a `[L, Hkv, T, D]` array)
/// from a compact `[L, Hkv, count, D]` slice.
pub fn scatter_tokens(dst: &mut [f32], dims: KvDims, total_tokens: usize,
                      t0: usize, slice: &[f32]) {
    let count = slice.len() / (dims.n_layers * dims.n_kv_heads * dims.head_dim);
    assert_eq!(slice.len(), dims.elems(count), "slice shape mismatch");
    assert!(t0 + count <= total_tokens, "scatter out of range");
    let d = dims.head_dim;
    let mut src_off = 0;
    for l in 0..dims.n_layers {
        for h in 0..dims.n_kv_heads {
            let base = (l * dims.n_kv_heads + h) * total_tokens * d;
            dst[base + t0 * d..base + (t0 + count) * d]
                .copy_from_slice(&slice[src_off..src_off + count * d]);
            src_off += count * d;
        }
    }
}

/// Pack one chunk's K and V slices into a self-contained blob.
pub fn pack_chunk(k: &[f32], v: &[f32]) -> Vec<u8> {
    assert_eq!(k.len(), v.len());
    let mut out = Vec::with_capacity((k.len() + v.len()) * 4);
    for x in k.iter().chain(v.iter()) {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Split a blob back into (K, V) f32 slices.
pub fn unpack_chunk(blob: &[u8]) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(blob.len() % 8, 0, "blob must hold equal K and V halves");
    let half = blob.len() / 2;
    let parse = |bytes: &[u8]| -> Vec<f32> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    (parse(&blob[..half]), parse(&blob[half..]))
}

/// Extract per-chunk blobs from a prefill's `(new_k, new_v)` output.
/// Only whole chunks are produced; the tail is never cached.
pub fn chunks_from_new_kv(new_k: &[f32], new_v: &[f32], dims: KvDims,
                          bucket_tokens: usize, valid_tokens: usize,
                          chunk_tokens: usize) -> Vec<Vec<u8>> {
    let n_chunks = valid_tokens / chunk_tokens;
    (0..n_chunks)
        .map(|c| {
            let k = slice_tokens(new_k, dims, bucket_tokens, c * chunk_tokens, chunk_tokens);
            let v = slice_tokens(new_v, dims, bucket_tokens, c * chunk_tokens, chunk_tokens);
            pack_chunk(&k, &v)
        })
        .collect()
}

/// Assemble `past_k` / `past_v` bucket arrays (`[L, Hkv, P, D]`, zero
/// padded) from chunk blobs.
pub fn assemble_past(blobs: &[Vec<u8>], dims: KvDims, bucket_tokens: usize,
                     chunk_tokens: usize) -> (Vec<f32>, Vec<f32>) {
    assert!(blobs.len() * chunk_tokens <= bucket_tokens, "past overflows bucket");
    let mut k = vec![0.0f32; dims.elems(bucket_tokens)];
    let mut v = vec![0.0f32; dims.elems(bucket_tokens)];
    for (c, blob) in blobs.iter().enumerate() {
        let (bk, bv) = unpack_chunk(blob);
        scatter_tokens(&mut k, dims, bucket_tokens, c * chunk_tokens, &bk);
        scatter_tokens(&mut v, dims, bucket_tokens, c * chunk_tokens, &bv);
    }
    (k, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const DIMS: KvDims = KvDims {
        n_layers: 2,
        n_kv_heads: 3,
        head_dim: 4,
    };

    fn random_kv(tokens: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..DIMS.elems(tokens)).map(|_| rng.f32()).collect()
    }

    #[test]
    fn slice_then_scatter_round_trips() {
        let src = random_kv(10, 1);
        let slice = slice_tokens(&src, DIMS, 10, 3, 4);
        assert_eq!(slice.len(), DIMS.elems(4));
        let mut dst = vec![0.0f32; DIMS.elems(10)];
        scatter_tokens(&mut dst, DIMS, 10, 3, &slice);
        let back = slice_tokens(&dst, DIMS, 10, 3, 4);
        assert_eq!(slice, back);
    }

    #[test]
    fn pack_unpack_round_trips() {
        let k = random_kv(4, 2);
        let v = random_kv(4, 3);
        let blob = pack_chunk(&k, &v);
        assert_eq!(blob.len(), DIMS.chunk_bytes(4));
        let (k2, v2) = unpack_chunk(&blob);
        assert_eq!(k, k2);
        assert_eq!(v, v2);
    }

    #[test]
    fn full_chunking_round_trip() {
        // new KV of 10 tokens in a 12-token bucket, chunk=4: chunks
        // cover tokens 0..8; reassembling into a past bucket of 8
        // reproduces the original values.
        let bucket = 12;
        let valid = 10;
        let chunk = 4;
        let new_k = random_kv(bucket, 4);
        let new_v = random_kv(bucket, 5);
        let blobs = chunks_from_new_kv(&new_k, &new_v, DIMS, bucket, valid, chunk);
        assert_eq!(blobs.len(), 2);
        let (past_k, past_v) = assemble_past(&blobs, DIMS, 8, chunk);
        assert_eq!(slice_tokens(&past_k, DIMS, 8, 0, 8),
                   slice_tokens(&new_k, DIMS, bucket, 0, 8));
        assert_eq!(slice_tokens(&past_v, DIMS, 8, 0, 8),
                   slice_tokens(&new_v, DIMS, bucket, 0, 8));
    }

    #[test]
    fn assemble_pads_with_zeros() {
        let new_k = random_kv(4, 6);
        let new_v = random_kv(4, 7);
        let blobs = chunks_from_new_kv(&new_k, &new_v, DIMS, 4, 4, 4);
        let (past_k, _) = assemble_past(&blobs, DIMS, 8, 4);
        // tokens 4..8 are padding
        let pad = slice_tokens(&past_k, DIMS, 8, 4, 4);
        assert!(pad.iter().all(|x| *x == 0.0));
    }

    #[test]
    #[should_panic(expected = "past overflows bucket")]
    fn overflow_caught() {
        let blobs = vec![vec![0u8; DIMS.chunk_bytes(4)]; 3];
        assemble_past(&blobs, DIMS, 8, 4);
    }
}
