//! AOT artifact manifest: the contract `python/compile/aot.py` writes
//! and the PJRT runtime consumes (model geometry, parameter table,
//! shape-bucket table, weight blob).

use crate::runtime::kv::KvDims;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One exported HLO artifact.
#[derive(Clone, Debug, PartialEq)]
pub enum Artifact {
    Prefill { past: usize, new: usize, file: String },
    Decode { max_len: usize, file: String },
}

/// One parameter's name + shape (ABI order).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub chunk_tokens: usize,
    pub params: Vec<ParamSpec>,
    pub weights_file: String,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let model = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let get = |obj: &Json, k: &str| -> Result<usize> {
            obj.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing model.{k}"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing artifacts"))?
            .iter()
            .map(|a| -> Result<Artifact> {
                let file = a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact file"))?
                    .to_string();
                match a.get("kind").and_then(Json::as_str) {
                    Some("prefill") => Ok(Artifact::Prefill {
                        past: a.get("past").and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("past"))?,
                        new: a.get("new").and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("new"))?,
                        file,
                    }),
                    Some("decode") => Ok(Artifact::Decode {
                        max_len: a.get("max_len").and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("max_len"))?,
                        file,
                    }),
                    _ => bail!("unknown artifact kind"),
                }
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            vocab: get(model, "vocab")?,
            d_model: get(model, "d_model")?,
            n_layers: get(model, "n_layers")?,
            n_heads: get(model, "n_heads")?,
            n_kv_heads: get(model, "n_kv_heads")?,
            head_dim: get(model, "head_dim")?,
            chunk_tokens: j
                .get("chunk_tokens")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing chunk_tokens"))?,
            weights_file: j
                .get("weights_file")
                .and_then(Json::as_str)
                .unwrap_or("weights.bin")
                .to_string(),
            params,
            artifacts,
            dir,
        })
    }

    pub fn kv_dims(&self) -> KvDims {
        KvDims {
            n_layers: self.n_layers,
            n_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim,
        }
    }

    /// Smallest prefill bucket with `past >= past_tokens` and
    /// `new >= new_tokens`.
    pub fn pick_prefill_bucket(&self, past_tokens: usize, new_tokens: usize)
        -> Option<(usize, usize)> {
        self.artifacts
            .iter()
            .filter_map(|a| match a {
                Artifact::Prefill { past, new, .. }
                    if *past >= past_tokens && *new >= new_tokens =>
                {
                    Some((*past, *new))
                }
                _ => None,
            })
            .min_by_key(|(p, n)| (*p + *n, *p))
    }

    /// Largest available (past, new) bucket — the capacity limit of the
    /// real serving path.
    pub fn max_bucket(&self) -> (usize, usize) {
        self.artifacts
            .iter()
            .filter_map(|a| match a {
                Artifact::Prefill { past, new, .. } => Some((*past, *new)),
                _ => None,
            })
            .fold((0, 0), |(mp, mn), (p, n)| (mp.max(p), mn.max(n)))
    }

    pub fn prefill_file(&self, past: usize, new: usize) -> Option<PathBuf> {
        self.artifacts.iter().find_map(|a| match a {
            Artifact::Prefill { past: p, new: n, file }
                if *p == past && *n == new => Some(self.dir.join(file)),
            _ => None,
        })
    }

    pub fn decode_file(&self) -> Option<(usize, PathBuf)> {
        self.artifacts.iter().find_map(|a| match a {
            Artifact::Decode { max_len, file } => Some((*max_len, self.dir.join(file))),
            _ => None,
        })
    }

    /// Load `weights.bin` into per-parameter f32 vectors (ABI order).
    pub fn load_weights(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&self.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let total: usize = self.params.iter().map(ParamSpec::elems).sum();
        if bytes.len() != total * 4 {
            bail!("weights.bin is {} bytes, expected {}", bytes.len(), total * 4);
        }
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0;
        for p in &self.params {
            let n = p.elems();
            let v: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            out.push(v);
            off += n * 4;
        }
        Ok(out)
    }
}

/// Default artifacts directory: `$PCR_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("PCR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load(default_artifacts_dir()).ok()
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.n_kv_heads, 4);
        assert_eq!(m.chunk_tokens, 128);
        assert_eq!(m.params.len(), 4 * 9 + 3);
        assert_eq!(m.params[0].name, "embed");
        assert!(m.decode_file().is_some());
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = manifest() else { return };
        // exact fit
        assert_eq!(m.pick_prefill_bucket(128, 128), Some((128, 128)));
        // rounding up
        assert_eq!(m.pick_prefill_bucket(130, 100), Some((256, 128)));
        assert_eq!(m.pick_prefill_bucket(0, 1), Some((128, 128)));
        // too big
        assert_eq!(m.pick_prefill_bucket(4096, 128), None);
        assert_eq!(m.max_bucket(), (512, 512));
    }

    #[test]
    fn weights_match_param_table() {
        let Some(m) = manifest() else { return };
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), m.params.len());
        for (p, v) in m.params.iter().zip(&w) {
            assert_eq!(p.elems(), v.len());
        }
        // embed is vocab x d_model
        assert_eq!(m.params[0].shape, vec![m.vocab, m.d_model]);
    }
}
