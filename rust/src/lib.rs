//! # PCR — Prefetch-Enhanced Cache Reuse for Low-Latency RAG Serving
//!
//! Reproduction of *"PCR: A Prefetch-Enhanced Cache Reuse System for
//! Low-Latency RAG Serving"* (Wang et al., CS.DC 2026) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a serving
//!   coordinator with a prefix-tree KV cache across GPU/DRAM/SSD tiers
//!   ([`cache`]), look-ahead LRU eviction, layer-wise transfer/compute
//!   overlapping ([`sim::pipeline`]), and queue-based SSD→DRAM
//!   prefetching ([`serve`]).
//! * **L2/L1 (build-time Python)** — a small GQA transformer whose
//!   prefill consumes reused prefix KV, with the attention hot-spot as a
//!   Pallas kernel; AOT-lowered to HLO text and executed natively via
//!   the PJRT CPU client ([`runtime`]). Python never runs on the
//!   request path.
//!
//! # The tiered-transfer I/O subsystem
//!
//! Chunk *metadata* placement (which tier holds what) is decided by the
//! cache engine; chunk *bytes* are moved by the [`io`] subsystem — an
//! asynchronous [`TransferEngine`](io::TransferEngine) with two
//! priority lanes over dedicated `util::threadpool` workers:
//!
//! * the **demand lane** (chunks the request being scheduled needs now)
//!   strictly preempts the **prefetch lane** (speculative SSD→DRAM
//!   promotions from the waiting queue's look-ahead window), so a
//!   prefetch backlog can never inflate TTFT;
//! * at most one read is in flight per chunk — a demand fetch
//!   *upgrades* an in-flight prefetch instead of re-reading;
//! * cancellation tokens drop evicted/stale targets before they hit
//!   disk, and bounded queues reject (and count) overflow instead of
//!   buffering it.
//!
//! The real path ([`runtime::executor::PjrtExecutor`]) submits to the
//! engine and drains completions between requests; the virtual-time
//! simulator ([`serve::engine`]) models the identical lane semantics
//! with [`io::VirtualLanes`], so both report the same
//! [`IoStats`](io::IoStats) shape. Sized via the `[io]` config section
//! (`io.workers`, `io.demand_depth`, `io.prefetch_depth`).
//!
//! # Multi-replica cluster serving
//!
//! Above the single engine, [`cluster`] scales the same loop to N
//! replicas: a global prefix directory (chunk-hash → replica set, fed
//! by cache residency events) lets pluggable routing policies
//! (`round-robin`, `least-loaded`, `prefix-affinity`,
//! `affinity-balanced[:alpha]`) compute every replica's matched-prefix
//! length in O(depth) without touching replica-local trees. Configured
//! via the `[cluster]` section (`cluster.replicas`, `cluster.router`).
//!
//! Experiments (every table & figure of the paper) live in
//! `rust/benches/`; see DESIGN.md for the experiment index and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod bench;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod hw;
pub mod io;
pub mod obs;
pub mod rag;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

/// Crate version (also reported by the CLI and the HTTP server).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
