//! # PCR — Prefetch-Enhanced Cache Reuse for Low-Latency RAG Serving
//!
//! Reproduction of *"PCR: A Prefetch-Enhanced Cache Reuse System for
//! Low-Latency RAG Serving"* (Wang et al., CS.DC 2026) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a serving
//!   coordinator with a prefix-tree KV cache across GPU/DRAM/SSD tiers
//!   ([`cache`]), look-ahead LRU eviction, layer-wise transfer/compute
//!   overlapping ([`sim::pipeline`]), and queue-based SSD→DRAM
//!   prefetching ([`serve`]).
//! * **L2/L1 (build-time Python)** — a small GQA transformer whose
//!   prefill consumes reused prefix KV, with the attention hot-spot as a
//!   Pallas kernel; AOT-lowered to HLO text and executed natively via
//!   the PJRT CPU client ([`runtime`]). Python never runs on the
//!   request path.
//!
//! Experiments (every table & figure of the paper) live in
//! `rust/benches/`; see DESIGN.md for the experiment index and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod bench;
pub mod cache;
pub mod config;
pub mod hw;
pub mod rag;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

/// Crate version (also reported by the CLI and the HTTP server).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
