//! Deterministic, seeded fault injection for the tiered KV-cache path.
//!
//! PCR treats DRAM/SSD reuse as *best-effort acceleration over an
//! always-correct recompute path*: a failed or corrupted cache load
//! must degrade to a recompute, never fail the request. This module
//! provides the harness that proves it — a [`FaultPlan`] describing
//! *what* to break (rates + a seed), a [`FaultSession`] that makes the
//! per-chunk decisions and counts every injection, and two wrappers
//! that carry the plan into the real I/O path ([`FaultyStore`] below a
//! [`ChunkStore`], [`FaultySource`] below the transfer engine's
//! [`FetchSource`]).
//!
//! Every decision is a pure function of `(seed, fault kind, chunk
//! key)`: two sessions built from the same plan inject the *same*
//! faults in the *same* places, which is what lets the chaos proptest
//! replay a faulted run bit-for-bit and account for every injection.
//!
//! Fault model (see the module guide in [`crate::io`] for the full
//! degradation matrix):
//!
//! * **transient** — a read attempt fails with an error but the data is
//!   intact; bounded retry-with-backoff recovers it. A key decided
//!   flaky fails its first [`FaultPlan::transient_attempts`] attempts,
//!   so a retry bound below that count exhausts and degrades.
//! * **lost** — the stored bytes are permanently gone (medium failure).
//!   Reads miss; the chunk is quarantined and recomputed. Loss sticks
//!   to the key: a rewritten copy on the same "sector" is lost again.
//! * **corrupt** — the stored bytes are silently flipped. The checksum
//!   catches it on read, the bad *copy* is quarantined, and the
//!   rewritten copy is clean (one-shot per key).
//! * **spike** — the read succeeds but takes [`FaultPlan::spike_seconds`]
//!   longer (latency injection only; no degradation).
//! * **replica kill** — cluster level: replica
//!   [`FaultPlan::kill_replica`] dies after
//!   [`FaultPlan::kill_after`] routed requests (see `cluster::sim`).

use crate::cache::chunk::ChunkKey;
use crate::cache::store::ChunkStore;
use crate::io::engine::FetchSource;
use crate::util::rng::splitmix64;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A seeded description of what to inject. All rates are probabilities
/// in `[0, 1]` applied per chunk key; `Default` injects nothing.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Seed for every per-key decision.
    pub seed: u64,
    /// Probability a key's reads fail transiently.
    pub transient: f64,
    /// How many consecutive attempts fail for a transient-flaky key.
    pub transient_attempts: u32,
    /// Probability a key's stored bytes are permanently lost.
    pub loss: f64,
    /// Probability a key's first stored copy is corrupted.
    pub corrupt: f64,
    /// Probability a key's reads take a latency spike.
    pub spike: f64,
    /// Extra read latency per spike, in seconds.
    pub spike_seconds: f64,
    /// Cluster: kill this replica index mid-run (`None` = nobody dies).
    pub kill_replica: Option<usize>,
    /// Cluster: the kill fires once this many requests have been routed.
    pub kill_after: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0xFA17,
            transient: 0.0,
            transient_attempts: 1,
            loss: 0.0,
            corrupt: 0.0,
            spike: 0.0,
            spike_seconds: 0.05,
            kill_replica: None,
            kill_after: 0,
        }
    }
}

/// Decision domains: each fault kind draws from its own stream so
/// (e.g.) raising the loss rate never changes which keys are flaky.
const D_LOSS: u64 = 1;
const D_CORRUPT: u64 = 2;
const D_TRANSIENT: u64 = 3;
const D_SPIKE: u64 = 4;

impl FaultPlan {
    /// Anything to inject at the chunk level?
    pub fn enabled(&self) -> bool {
        self.transient > 0.0 || self.loss > 0.0 || self.corrupt > 0.0 || self.spike > 0.0
    }

    /// Anything to inject at all (chunk or cluster level)?
    pub fn any(&self) -> bool {
        self.enabled() || self.kill_replica.is_some()
    }

    /// Deterministic uniform draw in `[0, 1)` for (kind, key).
    fn unit(&self, domain: u64, key: ChunkKey) -> f64 {
        let mut s = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(domain)
            ^ key.0;
        (splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Is this key's stored copy permanently lost?
    pub fn is_lost(&self, key: ChunkKey) -> bool {
        self.loss > 0.0 && self.unit(D_LOSS, key) < self.loss
    }

    /// Is this key's first stored copy corrupted?
    pub fn is_corrupted(&self, key: ChunkKey) -> bool {
        self.corrupt > 0.0 && self.unit(D_CORRUPT, key) < self.corrupt
    }

    /// How many consecutive read attempts fail for this key (0 = clean)?
    pub fn transient_failures(&self, key: ChunkKey) -> u32 {
        if self.transient > 0.0 && self.unit(D_TRANSIENT, key) < self.transient {
            self.transient_attempts.max(1)
        } else {
            0
        }
    }

    /// Does a read of this key take a latency spike?
    pub fn is_spiked(&self, key: ChunkKey) -> bool {
        self.spike > 0.0 && self.unit(D_SPIKE, key) < self.spike
    }
}

#[derive(Debug, Default)]
struct InjectedInner {
    lost: AtomicU64,
    corrupted: AtomicU64,
    retries: AtomicU64,
    exhausted: AtomicU64,
    spikes: AtomicU64,
}

/// Snapshot of everything a [`FaultSession`] has injected so far — the
/// chaos proptest's ground truth to reconcile degradation counters
/// against.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Injected {
    /// Reads that hit a permanently-lost copy.
    pub lost: u64,
    /// Corrupted copies detected (and therefore quarantined).
    pub corrupted: u64,
    /// Failed attempts that were retried (recovered or not).
    pub retries: u64,
    /// Reads whose retries ran out (degraded to recompute).
    pub exhausted: u64,
    /// Latency spikes served.
    pub spikes: u64,
}

impl Injected {
    /// Injections that force the degrade-to-recompute path.
    pub fn degrading(&self) -> u64 {
        self.lost + self.corrupted + self.exhausted
    }
}

/// Outcome of the transient-fault decision for one read, against a
/// caller-supplied retry bound.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transient {
    /// No transient fault: the first attempt succeeds.
    Clean,
    /// The first `n` attempts failed; retries recovered the read.
    Recovered(u32),
    /// The retry bound ran out: the read degrades to a miss.
    Exhausted(u32),
}

/// One run's fault state: the shared plan plus injection counters and
/// the per-key one-shot bookkeeping for corruption. Cheap to clone —
/// clones share counters and state.
#[derive(Clone, Debug, Default)]
pub struct FaultSession {
    plan: Arc<FaultPlan>,
    counts: Arc<InjectedInner>,
    /// Keys whose corrupted copy was already detected: the rewritten
    /// copy is clean (corruption is a property of one bad write).
    tripped: Arc<Mutex<HashSet<ChunkKey>>>,
}

impl FaultSession {
    pub fn new(plan: FaultPlan) -> Self {
        FaultSession {
            plan: Arc::new(plan),
            counts: Arc::new(InjectedInner::default()),
            tripped: Arc::new(Mutex::new(HashSet::new())),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Counting decision: is this read of `key` lost? (Sticky per key.)
    pub fn lost(&self, key: ChunkKey) -> bool {
        let hit = self.plan.is_lost(key);
        if hit {
            self.counts.lost.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Counting decision: does this read of `key` detect corruption?
    /// One-shot per key — the quarantined copy's replacement is clean.
    pub fn corrupted(&self, key: ChunkKey) -> bool {
        if !self.plan.is_corrupted(key) {
            return false;
        }
        let mut tripped = self.tripped.lock().unwrap_or_else(|p| p.into_inner());
        if !tripped.insert(key) {
            return false;
        }
        self.counts.corrupted.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Counting decision: transient outcome of one read of `key` under
    /// a `retry_limit`-retry budget (attempts = 1 + retry_limit).
    pub fn transient(&self, key: ChunkKey, retry_limit: u32) -> Transient {
        let fails = self.plan.transient_failures(key);
        if fails == 0 {
            return Transient::Clean;
        }
        let performed = fails.min(retry_limit);
        self.counts
            .retries
            .fetch_add(performed as u64, Ordering::Relaxed);
        if fails > retry_limit {
            self.counts.exhausted.fetch_add(1, Ordering::Relaxed);
            Transient::Exhausted(performed)
        } else {
            Transient::Recovered(performed)
        }
    }

    /// Counting decision: does this read of `key` take a spike?
    pub fn spiked(&self, key: ChunkKey) -> bool {
        let hit = self.plan.is_spiked(key);
        if hit {
            self.counts.spikes.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Snapshot the injection counters.
    pub fn injected(&self) -> Injected {
        Injected {
            lost: self.counts.lost.load(Ordering::Relaxed),
            corrupted: self.counts.corrupted.load(Ordering::Relaxed),
            retries: self.counts.retries.load(Ordering::Relaxed),
            exhausted: self.counts.exhausted.load(Ordering::Relaxed),
            spikes: self.counts.spikes.load(Ordering::Relaxed),
        }
    }
}

/// A [`ChunkStore`] wrapper that injects the session's plan below an
/// otherwise-healthy store: lost keys read as misses, corrupted copies
/// are deleted at detection (mirroring `FileStore`'s own checksum
/// quarantine) and read as misses, flaky keys error for their first
/// `transient_attempts` reads, spiked keys sleep. Writes and metadata
/// pass straight through — `contains` still reports lost keys present,
/// exactly the stale-metadata situation the read path must survive.
pub struct FaultyStore<S: ChunkStore> {
    inner: S,
    session: FaultSession,
    /// Failed attempts served so far per flaky key.
    attempts: Mutex<HashMap<ChunkKey, u32>>,
}

impl<S: ChunkStore> FaultyStore<S> {
    pub fn new(inner: S, session: FaultSession) -> Self {
        FaultyStore { inner, session, attempts: Mutex::new(HashMap::new()) }
    }

    pub fn session(&self) -> &FaultSession {
        &self.session
    }

    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Should this read attempt fail transiently? Burns one failure
    /// from the key's budget per call; counts the injection.
    fn transient_attempt(&self, key: ChunkKey) -> bool {
        let budget = self.session.plan.transient_failures(key);
        if budget == 0 {
            return false;
        }
        let mut attempts = self.attempts.lock().unwrap_or_else(|p| p.into_inner());
        let served = attempts.entry(key).or_insert(0);
        if *served < budget {
            *served += 1;
            self.session.counts.retries.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            // budget burnt: the key reads clean from here on
            false
        }
    }
}

impl<S: ChunkStore> ChunkStore for FaultyStore<S> {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        self.inner.put(key, data)
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        if self.session.lost(key) {
            return Ok(None);
        }
        if self.transient_attempt(key) {
            return Err(anyhow!("injected transient read error for {key:?}"));
        }
        if self.session.spiked(key) {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                self.session.plan.spike_seconds,
            ));
        }
        if self.session.corrupted(key) {
            // checksum mismatch: the bad copy is quarantined (deleted);
            // FaultyStore can't mutate through &self, so corruption
            // reads as a miss and the next put rewrites a clean copy.
            return Ok(None);
        }
        self.inner.get(key)
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        self.inner.delete(key)
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.inner.contains(key)
    }

    fn bytes_used(&self) -> u64 {
        self.inner.bytes_used()
    }
}

/// A [`FetchSource`] wrapper carrying the plan into the transfer
/// engine: lost/corrupted keys fetch as `Ok(None)` (miss), flaky keys
/// error for their first `transient_attempts` fetches (exercising the
/// engine's bounded retry), spiked keys sleep before serving.
pub struct FaultySource {
    inner: Arc<dyn FetchSource>,
    session: FaultSession,
    attempts: Mutex<HashMap<ChunkKey, u32>>,
}

impl FaultySource {
    pub fn new(inner: Arc<dyn FetchSource>, session: FaultSession) -> Self {
        FaultySource { inner, session, attempts: Mutex::new(HashMap::new()) }
    }

    pub fn session(&self) -> &FaultSession {
        &self.session
    }
}

impl FetchSource for FaultySource {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        if self.session.lost(key) {
            return Ok(None);
        }
        let budget = self.session.plan.transient_failures(key);
        if budget > 0 {
            let mut attempts = self.attempts.lock().unwrap_or_else(|p| p.into_inner());
            let served = attempts.entry(key).or_insert(0);
            if *served < budget {
                *served += 1;
                self.session.counts.retries.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("injected transient fetch error for {key:?}"));
            }
        }
        if self.session.spiked(key) {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                self.session.plan.spike_seconds,
            ));
        }
        if self.session.corrupted(key) {
            return Ok(None);
        }
        self.inner.fetch(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::store::MemStore;

    fn k(x: u64) -> ChunkKey {
        ChunkKey(x)
    }

    #[test]
    fn default_plan_injects_nothing() {
        let plan = FaultPlan::default();
        assert!(!plan.enabled());
        assert!(!plan.any());
        for i in 0..1000 {
            assert!(!plan.is_lost(k(i)));
            assert!(!plan.is_corrupted(k(i)));
            assert_eq!(plan.transient_failures(k(i)), 0);
            assert!(!plan.is_spiked(k(i)));
        }
    }

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        let plan = FaultPlan {
            seed: 42,
            loss: 0.1,
            corrupt: 0.2,
            transient: 0.3,
            spike: 0.05,
            ..FaultPlan::default()
        };
        let twin = plan.clone();
        let n = 20_000u64;
        let (mut lost, mut corrupt, mut flaky, mut spiked) = (0u64, 0u64, 0u64, 0u64);
        for i in 0..n {
            assert_eq!(plan.is_lost(k(i)), twin.is_lost(k(i)));
            assert_eq!(plan.is_corrupted(k(i)), twin.is_corrupted(k(i)));
            assert_eq!(plan.transient_failures(k(i)), twin.transient_failures(k(i)));
            assert_eq!(plan.is_spiked(k(i)), twin.is_spiked(k(i)));
            lost += plan.is_lost(k(i)) as u64;
            corrupt += plan.is_corrupted(k(i)) as u64;
            flaky += (plan.transient_failures(k(i)) > 0) as u64;
            spiked += plan.is_spiked(k(i)) as u64;
        }
        // rates land near their targets (loose 30% relative tolerance)
        let near = |hits: u64, rate: f64| {
            let expect = n as f64 * rate;
            (hits as f64 - expect).abs() < expect * 0.3
        };
        assert!(near(lost, 0.1), "lost {lost}");
        assert!(near(corrupt, 0.2), "corrupt {corrupt}");
        assert!(near(flaky, 0.3), "flaky {flaky}");
        assert!(near(spiked, 0.05), "spiked {spiked}");
    }

    #[test]
    fn domains_are_independent() {
        // raising the loss rate must not change which keys are flaky
        let a = FaultPlan { seed: 7, transient: 0.3, loss: 0.0, ..FaultPlan::default() };
        let b = FaultPlan { seed: 7, transient: 0.3, loss: 0.9, ..FaultPlan::default() };
        for i in 0..2000 {
            assert_eq!(a.transient_failures(k(i)), b.transient_failures(k(i)));
        }
    }

    #[test]
    fn session_counts_each_injection() {
        let plan = FaultPlan { seed: 1, loss: 1.0, ..FaultPlan::default() };
        let s = FaultSession::new(plan);
        assert!(s.lost(k(1)));
        assert!(s.lost(k(1))); // sticky and counted again
        assert_eq!(s.injected().lost, 2);
        assert_eq!(s.injected().degrading(), 2);
    }

    #[test]
    fn corruption_is_one_shot_per_key() {
        let plan = FaultPlan { seed: 1, corrupt: 1.0, ..FaultPlan::default() };
        let s = FaultSession::new(plan);
        assert!(s.corrupted(k(9)));
        assert!(!s.corrupted(k(9)), "quarantined copy's replacement is clean");
        assert_eq!(s.injected().corrupted, 1);
    }

    #[test]
    fn transient_outcome_respects_retry_limit() {
        let plan = FaultPlan {
            seed: 1,
            transient: 1.0,
            transient_attempts: 3,
            ..FaultPlan::default()
        };
        let s = FaultSession::new(plan);
        assert_eq!(s.transient(k(5), 5), Transient::Recovered(3));
        assert_eq!(s.transient(k(5), 2), Transient::Exhausted(2));
        assert_eq!(s.transient(k(5), 0), Transient::Exhausted(0));
        let i = s.injected();
        assert_eq!(i.retries, 5);
        assert_eq!(i.exhausted, 2);
        let clean = FaultSession::new(FaultPlan::default());
        assert_eq!(clean.transient(k(5), 2), Transient::Clean);
    }

    #[test]
    fn faulty_store_lost_reads_miss_but_metadata_survives() {
        let mut inner = MemStore::default();
        inner.put(k(3), b"abc").unwrap();
        let store = FaultyStore::new(
            inner,
            FaultSession::new(FaultPlan { seed: 1, loss: 1.0, ..FaultPlan::default() }),
        );
        assert!(store.contains(k(3)), "metadata still thinks it's there");
        assert!(store.get(k(3)).unwrap().is_none(), "the read discovers the loss");
        assert_eq!(store.session().injected().lost, 1);
    }

    #[test]
    fn faulty_store_transient_burns_budget_then_serves() {
        let mut inner = MemStore::default();
        inner.put(k(4), b"data").unwrap();
        let store = FaultyStore::new(
            inner,
            FaultSession::new(FaultPlan {
                seed: 1,
                transient: 1.0,
                transient_attempts: 2,
                ..FaultPlan::default()
            }),
        );
        assert!(store.get(k(4)).is_err());
        assert!(store.get(k(4)).is_err());
        assert_eq!(store.get(k(4)).unwrap().unwrap(), b"data");
        assert_eq!(store.session().injected().retries, 2);
    }

    #[test]
    fn faulty_store_corruption_reads_miss_once() {
        let mut inner = MemStore::default();
        inner.put(k(8), b"body").unwrap();
        let store = FaultyStore::new(
            inner,
            FaultSession::new(FaultPlan { seed: 1, corrupt: 1.0, ..FaultPlan::default() }),
        );
        assert!(store.get(k(8)).unwrap().is_none(), "first read detects corruption");
        assert_eq!(store.get(k(8)).unwrap().unwrap(), b"body", "rewrite-free copy is clean");
        assert_eq!(store.session().injected().corrupted, 1);
    }

    #[test]
    fn faulty_source_injects_through_fetch() {
        let mut inner = MemStore::default();
        inner.put(k(6), b"zz").unwrap();
        let src: Arc<dyn FetchSource> = Arc::new(std::sync::RwLock::new(inner));
        let fs = FaultySource::new(
            src,
            FaultSession::new(FaultPlan {
                seed: 1,
                transient: 1.0,
                transient_attempts: 1,
                ..FaultPlan::default()
            }),
        );
        assert!(fs.fetch(k(6)).is_err());
        assert_eq!(fs.fetch(k(6)).unwrap().unwrap(), b"zz");
        assert!(fs.fetch(k(7)).is_err(), "unknown keys are flaky too at rate 1.0");
        assert!(fs.fetch(k(7)).unwrap().is_none(), "budget burnt: clean read misses");
    }
}
