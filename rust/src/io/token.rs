//! Cancellation tokens for in-flight transfers.
//!
//! A [`CancelToken`] is a cheaply clonable flag shared between the
//! submitter and the transfer workers. Cancelling is *lazy*: the ticket
//! stays queued, but a worker observing a cancelled token drops it
//! before touching the source store (and re-checks after the read, so a
//! cancel that races with the read still suppresses the completion).
//! The engine guarantees that **no completion is ever delivered for a
//! cancelled token** — property-checked in `io::engine` tests.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shared cancellation flag for one submitted transfer.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_is_sticky_and_shared() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!t.is_cancelled());
        u.cancel();
        assert!(t.is_cancelled());
        u.cancel(); // idempotent
        assert!(u.is_cancelled());
    }
}
