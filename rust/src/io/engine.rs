//! The asynchronous [`TransferEngine`]: dual-lane SSD→DRAM chunk reads
//! over `util::threadpool` workers.
//!
//! Design contract (see the module docs of [`crate::io`] for the lane
//! semantics):
//!
//! * `submit` never blocks and never touches disk — it either queues a
//!   ticket, coalesces onto an in-flight one (`Deduped` / `Upgraded`),
//!   or refuses under backpressure (`Rejected`).
//! * Workers drain the demand queue strictly before the prefetch queue
//!   and FIFO within each lane.
//! * At most one in-flight ticket exists per chunk key; a completed or
//!   cancelled ticket frees the key for resubmission.
//! * A cancelled token never produces a completion (checked both before
//!   and after the read, so cancellation racing the read still wins).
//! * Promotion into DRAM is the caller's job: completions carry raw
//!   bytes so cache-metadata mutation stays on the scheduler thread.
//! * A read that errors is retried up to [`IoConfig::retries`] times
//!   with exponential backoff before the ticket fails (transient I/O
//!   errors degrade to a recompute, not a crash — see the failure
//!   model in [`crate::io`]).
//! * A source that *panics* never takes the engine down: the panic is
//!   contained to the worker, the in-flight ticket resolves as a
//!   failed completion, and the worker respawns
//!   ([`IoStats::worker_respawns`]). All engine locks recover from
//!   poisoning, so a dead worker cannot wedge submitters either.

use crate::cache::chunk::ChunkKey;
use crate::cache::store::ChunkStore;
use crate::io::token::CancelToken;
use crate::io::{IoConfig, IoStats, Lane};
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

/// Poison-recovering lock: a worker that panicked while holding the
/// lock leaves the data behind, and every mutation of engine state is
/// written to stay consistent at lock-release points — so the right
/// response to poisoning is to keep going, not to cascade the panic
/// into every thread that touches the engine.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Read-side source of chunk bytes, shared with the worker threads.
///
/// Blanket impls cover the repo's stores behind the standard locks:
/// `RwLock<FileStore>` gives concurrent reads (`ChunkStore::get` takes
/// `&self`); `Mutex<S>` serialises and suits tests. Both recover from
/// poisoning — a panic elsewhere must not turn every subsequent fetch
/// into a panic.
pub trait FetchSource: Send + Sync {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>>;
}

impl<S: ChunkStore + Sync> FetchSource for RwLock<S> {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        self.read().unwrap_or_else(|p| p.into_inner()).get(key)
    }
}

impl<S: ChunkStore> FetchSource for Mutex<S> {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        self.lock().unwrap_or_else(|p| p.into_inner()).get(key)
    }
}

/// Outcome of one `submit` call.
#[derive(Debug)]
pub enum Submit {
    /// Accepted; the token cancels this ticket.
    Queued(CancelToken),
    /// The key is already in flight on the same (or demand) lane.
    Deduped,
    /// A demand submit found an in-flight *prefetch* ticket and
    /// promoted it: the chunk will be read once, at demand priority.
    Upgraded,
    /// The lane queue is full (backpressure).
    Rejected,
}

impl Submit {
    pub fn accepted(&self) -> bool {
        !matches!(self, Submit::Rejected)
    }
}

/// One finished (or failed) read, delivered via `drain`/`take_blocking`.
#[derive(Debug)]
pub struct Completion {
    pub key: ChunkKey,
    /// Lane the ticket was *served* on (demand after an upgrade).
    pub lane: Lane,
    /// True iff a prefetch ticket was upgraded to demand priority.
    pub upgraded: bool,
    pub data: Result<Vec<u8>>,
    /// Seconds spent queued before a worker picked the ticket up.
    pub wait_seconds: f64,
    /// Seconds spent reading from the source.
    pub read_seconds: f64,
}

struct Ticket {
    key: ChunkKey,
    enqueued: Instant,
}

struct Entry {
    token: CancelToken,
    lane: Lane,
    upgraded: bool,
}

#[derive(Default)]
struct State {
    demand_q: VecDeque<Ticket>,
    prefetch_q: VecDeque<Ticket>,
    /// One entry per key with a queued or executing ticket.
    inflight: HashMap<ChunkKey, Entry>,
    completions: VecDeque<Completion>,
    stats: IoStats,
    paused: bool,
    shutdown: bool,
    /// Per-worker slot holding the key that worker is reading right
    /// now. If the worker dies mid-read, the respawn wrapper turns the
    /// slot's ticket into a failed completion instead of leaving the
    /// key wedged in `inflight` forever.
    executing: Vec<Option<ChunkKey>>,
}

struct Shared {
    state: Mutex<State>,
    /// Signalled when work arrives / pause lifts / shutdown starts.
    work: Condvar,
    /// Signalled when a completion lands or a ticket dies.
    done: Condvar,
}

/// Asynchronous dual-lane chunk mover. See module docs.
pub struct TransferEngine {
    shared: Arc<Shared>,
    cfg: IoConfig,
    // Dropped after the custom Drop body flips `shutdown`, so the
    // pool's join sees exiting workers.
    _pool: ThreadPool,
}

impl TransferEngine {
    pub fn new(cfg: IoConfig, source: Arc<dyn FetchSource>) -> TransferEngine {
        let workers = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                executing: vec![None; workers],
                ..State::default()
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let pool = ThreadPool::new(workers, "io");
        for wid in 0..workers {
            let shared = Arc::clone(&shared);
            let source = Arc::clone(&source);
            pool.submit(move || worker_entry(&shared, &*source, wid, cfg));
        }
        TransferEngine {
            shared,
            cfg,
            _pool: pool,
        }
    }

    pub fn config(&self) -> IoConfig {
        self.cfg
    }

    /// Queue a read of `key` on `lane`. Non-blocking; see [`Submit`].
    pub fn submit(&self, key: ChunkKey, lane: Lane) -> Submit {
        let mut st = lock(&self.shared.state);
        if let Some(cur_lane) = st.inflight.get(&key).map(|e| e.lane) {
            if lane == Lane::Demand && cur_lane == Lane::Prefetch {
                // Upgrade: move the queued ticket to the demand lane; a
                // ticket already at a worker keeps running but its
                // completion is re-labelled demand.
                if let Some(pos) = st.prefetch_q.iter().position(|t| t.key == key) {
                    if let Some(t) = st.prefetch_q.remove(pos) {
                        st.demand_q.push_back(t);
                    }
                }
                let e = st.inflight.get_mut(&key).expect("entry just observed");
                e.lane = Lane::Demand;
                e.upgraded = true;
                st.stats.upgraded += 1;
                crate::log_trace!("upgrade {:016x} to demand priority", key.0);
                self.shared.work.notify_all();
                return Submit::Upgraded;
            }
            st.stats.lane_mut(lane).deduped += 1;
            return Submit::Deduped;
        }
        let full = match lane {
            Lane::Demand => st.demand_q.len() >= self.cfg.demand_depth.max(1),
            Lane::Prefetch => st.prefetch_q.len() >= self.cfg.prefetch_depth.max(1),
        };
        if full {
            st.stats.lane_mut(lane).rejected += 1;
            crate::log_debug!("{} lane full, rejected {:016x}", lane.name(), key.0);
            return Submit::Rejected;
        }
        let token = CancelToken::new();
        st.inflight.insert(
            key,
            Entry {
                token: token.clone(),
                lane,
                upgraded: false,
            },
        );
        let ticket = Ticket {
            key,
            enqueued: Instant::now(),
        };
        match lane {
            Lane::Demand => st.demand_q.push_back(ticket),
            Lane::Prefetch => st.prefetch_q.push_back(ticket),
        }
        st.stats.lane_mut(lane).submitted += 1;
        self.shared.work.notify_one();
        Submit::Queued(token)
    }

    /// Cancel the in-flight ticket for `key`, if any. Returns whether a
    /// ticket was found. (Equivalent to cancelling the submit token.)
    pub fn cancel(&self, key: ChunkKey) -> bool {
        let st = lock(&self.shared.state);
        match st.inflight.get(&key) {
            Some(e) => {
                e.token.cancel();
                true
            }
            None => false,
        }
    }

    /// Stop workers from picking up new tickets (submits still queue).
    /// Used to stage a burst atomically; pair with [`Self::resume`].
    pub fn pause(&self) {
        lock(&self.shared.state).paused = true;
    }

    pub fn resume(&self) {
        lock(&self.shared.state).paused = false;
        self.shared.work.notify_all();
    }

    /// Pop every completion delivered so far (the scheduler's per-tick
    /// drain; promotion into DRAM happens at the call site).
    pub fn drain(&self) -> Vec<Completion> {
        let mut st = lock(&self.shared.state);
        st.completions.drain(..).collect()
    }

    /// Block until the completion for `key` arrives, then take it.
    /// Returns `None` if `key` is neither in flight nor completed (e.g.
    /// never submitted, or cancelled and reaped), or on timeout.
    pub fn take_blocking(&self, key: ChunkKey, timeout: Duration) -> Option<Completion> {
        let deadline = Instant::now() + timeout;
        let mut st = lock(&self.shared.state);
        loop {
            if let Some(pos) = st.completions.iter().position(|c| c.key == key) {
                return st.completions.remove(pos);
            }
            if !st.inflight.contains_key(&key) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .shared
                .done
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
        }
    }

    /// Busy-poll until no ticket is queued or executing (tests/benches).
    pub fn wait_quiescent(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let st = lock(&self.shared.state);
                if st.inflight.is_empty() {
                    return true;
                }
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    pub fn stats(&self) -> IoStats {
        lock(&self.shared.state).stats
    }

    /// Tickets currently queued (not yet picked up) on `lane`.
    pub fn queue_depth(&self, lane: Lane) -> usize {
        let st = lock(&self.shared.state);
        match lane {
            Lane::Demand => st.demand_q.len(),
            Lane::Prefetch => st.prefetch_q.len(),
        }
    }

    /// Keys with a queued or executing ticket.
    pub fn inflight_count(&self) -> usize {
        lock(&self.shared.state).inflight.len()
    }

    /// Completions delivered but not yet drained.
    pub fn completed_pending(&self) -> usize {
        lock(&self.shared.state).completions.len()
    }
}

impl Drop for TransferEngine {
    fn drop(&mut self) {
        lock(&self.shared.state).shutdown = true;
        self.shared.work.notify_all();
        // `_pool` drops next and joins the exiting workers.
    }
}

/// Worker thread body: run [`worker_loop`] forever, containing any
/// panic that escapes it (a panicking [`FetchSource`] is user code).
/// On a panic the in-flight ticket — parked in the worker's
/// `executing` slot — resolves as a failed completion so its key is
/// never wedged, the respawn is counted, and the loop re-enters.
fn worker_entry(shared: &Shared, source: &dyn FetchSource, wid: usize, cfg: IoConfig) {
    loop {
        let exited = catch_unwind(AssertUnwindSafe(|| worker_loop(shared, source, wid, cfg)));
        match exited {
            Ok(()) => return, // clean shutdown
            Err(_) => {
                crate::log_warn!("io worker {wid} respawned after a source panic");
                let mut st = lock(&shared.state);
                st.stats.worker_respawns += 1;
                if let Some(key) = st.executing[wid].take() {
                    if let Some(entry) = st.inflight.remove(&key) {
                        st.stats.lane_mut(entry.lane).failed += 1;
                        st.completions.push_back(Completion {
                            key,
                            lane: entry.lane,
                            upgraded: entry.upgraded,
                            data: Err(anyhow!("io worker panicked while reading {:016x}", key.0)),
                            wait_seconds: 0.0,
                            read_seconds: 0.0,
                        });
                    }
                }
                let stop = st.shutdown;
                drop(st);
                shared.done.notify_all();
                if stop {
                    return;
                }
            }
        }
    }
}

fn worker_loop(shared: &Shared, source: &dyn FetchSource, wid: usize, cfg: IoConfig) {
    loop {
        // Pop the next ticket: demand first, FIFO within a lane. The
        // cancellation check happens under the same lock, so a ticket
        // observed cancelled here provably never reached the source.
        let (ticket, token, wait_s) = {
            let mut st = lock(&shared.state);
            'pop: loop {
                if st.shutdown {
                    return;
                }
                if !st.paused {
                    let popped = st
                        .demand_q
                        .pop_front()
                        .or_else(|| st.prefetch_q.pop_front());
                    if let Some(t) = popped {
                        let (lane, token, cancelled) = match st.inflight.get(&t.key) {
                            Some(e) => (e.lane, e.token.clone(), e.token.is_cancelled()),
                            None => continue 'pop, // reaped: stale ticket
                        };
                        if cancelled {
                            st.inflight.remove(&t.key);
                            st.stats.lane_mut(lane).cancelled += 1;
                            shared.done.notify_all();
                            continue 'pop;
                        }
                        let wait = t.enqueued.elapsed().as_secs_f64();
                        st.executing[wid] = Some(t.key);
                        break 'pop (t, token, wait);
                    }
                }
                st = shared.work.wait(st).unwrap_or_else(|p| p.into_inner());
            }
        };

        // Bounded retry with exponential backoff: an `Err` from the
        // source is (presumed) transient; a miss (`Ok(None)`) is
        // definitive and never retried. Cancellation is honoured
        // between attempts so a cancelled ticket stops burning disk.
        let t0 = Instant::now();
        let mut retries = 0u32;
        let mut fetched = source.fetch(ticket.key);
        while fetched.is_err() && retries < cfg.retries && !token.is_cancelled() {
            crate::log_debug!(
                "transient read error on {:016x}, retry {}/{}",
                ticket.key.0,
                retries + 1,
                cfg.retries
            );
            let backoff = cfg.retry_backoff_ms << retries.min(6);
            if backoff > 0 {
                std::thread::sleep(Duration::from_millis(backoff));
            }
            retries += 1;
            fetched = source.fetch(ticket.key);
        }
        let read_s = t0.elapsed().as_secs_f64();

        let mut st = lock(&shared.state);
        st.executing[wid] = None;
        let entry = match st.inflight.remove(&ticket.key) {
            Some(e) => e,
            None => continue,
        };
        if token.is_cancelled() {
            // Cancel raced the read: suppress the completion.
            let s = st.stats.lane_mut(entry.lane);
            s.cancelled += 1;
            s.retries += retries as u64;
            shared.done.notify_all();
            continue;
        }
        let lane = entry.lane;
        let data = {
            let s = st.stats.lane_mut(lane);
            s.wait_seconds += wait_s;
            s.serve_seconds += read_s;
            s.retries += retries as u64;
            match fetched {
                Ok(Some(bytes)) => {
                    s.completed += 1;
                    s.bytes_moved += bytes.len() as u64;
                    Ok(bytes)
                }
                Ok(None) => {
                    s.failed += 1;
                    crate::log_debug!("chunk {:016x} missing from source", ticket.key.0);
                    Err(anyhow!("chunk {:016x} missing from source", ticket.key.0))
                }
                Err(e) => {
                    s.failed += 1;
                    crate::log_debug!("read of {:016x} failed: {e:#}", ticket.key.0);
                    Err(e)
                }
            }
        };
        st.completions.push_back(Completion {
            key: ticket.key,
            lane,
            upgraded: entry.upgraded,
            data,
            wait_seconds: wait_s,
            read_seconds: read_s,
        });
        shared.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::store::MemStore;
    use crate::util::proptest::{check, forall};

    fn key(i: u64) -> ChunkKey {
        ChunkKey(0x1000 + i)
    }

    /// A MemStore-backed source with optional per-read delay.
    fn source(n_keys: u64, delay: Duration) -> Arc<dyn FetchSource> {
        struct Slow {
            store: Mutex<MemStore>,
            delay: Duration,
        }
        impl FetchSource for Slow {
            fn fetch(&self, k: ChunkKey) -> Result<Option<Vec<u8>>> {
                if !self.delay.is_zero() {
                    std::thread::sleep(self.delay);
                }
                self.store.lock().unwrap().get(k)
            }
        }
        let mut store = MemStore::new();
        for i in 0..n_keys {
            store.put(key(i), &[i as u8; 8]).unwrap();
        }
        Arc::new(Slow {
            store: Mutex::new(store),
            delay,
        })
    }

    fn cfg(workers: usize) -> IoConfig {
        IoConfig {
            workers,
            demand_depth: 64,
            prefetch_depth: 64,
            ..IoConfig::default()
        }
    }

    const T: Duration = Duration::from_secs(10);

    #[test]
    fn demand_preempts_queued_prefetch_and_lanes_stay_fifo() {
        let eng = TransferEngine::new(cfg(1), source(16, Duration::ZERO));
        eng.pause();
        for i in 0..4 {
            assert!(matches!(eng.submit(key(i), Lane::Prefetch), Submit::Queued(_)));
        }
        for i in 4..6 {
            assert!(matches!(eng.submit(key(i), Lane::Demand), Submit::Queued(_)));
        }
        eng.resume();
        assert!(eng.wait_quiescent(T));
        let done = eng.drain();
        let order: Vec<u64> = done.iter().map(|c| c.key.0 - 0x1000).collect();
        // demand (FIFO) first, then prefetch (FIFO)
        assert_eq!(order, vec![4, 5, 0, 1, 2, 3]);
        assert!(done[0].lane == Lane::Demand && done[2].lane == Lane::Prefetch);
        let s = eng.stats();
        assert_eq!(s.demand.completed, 2);
        assert_eq!(s.prefetch.completed, 4);
        assert_eq!(s.demand.bytes_moved, 16);
    }

    #[test]
    fn demand_upgrade_serves_once() {
        let eng = TransferEngine::new(cfg(2), source(8, Duration::ZERO));
        eng.pause();
        assert!(matches!(eng.submit(key(3), Lane::Prefetch), Submit::Queued(_)));
        assert!(matches!(eng.submit(key(3), Lane::Demand), Submit::Upgraded));
        // further demand submits coalesce
        assert!(matches!(eng.submit(key(3), Lane::Demand), Submit::Deduped));
        eng.resume();
        let c = eng.take_blocking(key(3), T).expect("completion");
        assert_eq!(c.lane, Lane::Demand);
        assert!(c.upgraded);
        assert_eq!(c.data.unwrap(), vec![3u8; 8]);
        // exactly one completion existed for the key
        assert!(eng.drain().is_empty());
        let s = eng.stats();
        assert_eq!(s.upgraded, 1);
        assert_eq!(s.demand.deduped, 1);
        assert_eq!(s.prefetch.submitted, 1);
        assert_eq!(s.demand.submitted, 0);
        // key is free again after completion
        assert!(matches!(eng.submit(key(3), Lane::Demand), Submit::Queued(_)));
        assert!(eng.take_blocking(key(3), T).is_some());
    }

    #[test]
    fn duplicate_prefetch_submits_dedup() {
        let eng = TransferEngine::new(cfg(1), source(4, Duration::ZERO));
        eng.pause();
        assert!(matches!(eng.submit(key(0), Lane::Prefetch), Submit::Queued(_)));
        assert!(matches!(eng.submit(key(0), Lane::Prefetch), Submit::Deduped));
        eng.resume();
        assert!(eng.wait_quiescent(T));
        assert_eq!(eng.drain().len(), 1);
        assert_eq!(eng.stats().prefetch.deduped, 1);
    }

    #[test]
    fn backpressure_rejects_when_lane_full() {
        let eng = TransferEngine::new(
            IoConfig {
                workers: 1,
                demand_depth: 64,
                prefetch_depth: 2,
                ..IoConfig::default()
            },
            source(8, Duration::ZERO),
        );
        eng.pause();
        assert!(eng.submit(key(0), Lane::Prefetch).accepted());
        assert!(eng.submit(key(1), Lane::Prefetch).accepted());
        assert!(matches!(eng.submit(key(2), Lane::Prefetch), Submit::Rejected));
        assert!(matches!(eng.submit(key(3), Lane::Prefetch), Submit::Rejected));
        eng.resume();
        assert!(eng.wait_quiescent(T));
        assert_eq!(eng.stats().prefetch.rejected, 2);
        assert_eq!(eng.drain().len(), 2);
    }

    #[test]
    fn missing_key_fails_but_completes() {
        let eng = TransferEngine::new(cfg(1), source(1, Duration::ZERO));
        eng.submit(ChunkKey(0xDEAD), Lane::Demand);
        let c = eng.take_blocking(ChunkKey(0xDEAD), T).expect("completion");
        assert!(c.data.is_err());
        assert_eq!(eng.stats().demand.failed, 1);
        assert_eq!(eng.stats().demand.completed, 0);
    }

    #[test]
    fn cancelled_ticket_is_reaped_without_completion() {
        let eng = TransferEngine::new(cfg(1), source(4, Duration::ZERO));
        eng.pause();
        let tok = match eng.submit(key(1), Lane::Prefetch) {
            Submit::Queued(t) => t,
            other => panic!("{other:?}"),
        };
        eng.submit(key(2), Lane::Prefetch);
        tok.cancel();
        eng.resume();
        assert!(eng.wait_quiescent(T));
        let done = eng.drain();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].key, key(2));
        assert_eq!(eng.stats().prefetch.cancelled, 1);
        // take_blocking on the reaped key returns None, promptly
        assert!(eng.take_blocking(key(1), Duration::from_millis(50)).is_none());
    }

    #[test]
    fn cancel_by_key_matches_token_cancel() {
        let eng = TransferEngine::new(cfg(1), source(4, Duration::ZERO));
        eng.pause();
        eng.submit(key(0), Lane::Prefetch);
        assert!(eng.cancel(key(0)));
        assert!(!eng.cancel(key(3))); // nothing in flight
        eng.resume();
        assert!(eng.wait_quiescent(T));
        assert!(eng.drain().is_empty());
        assert_eq!(eng.stats().prefetch.cancelled, 1);
    }

    /// Satellite: property — no completion is ever delivered for a
    /// cancelled token, and every surviving submit completes exactly
    /// once. Pausing the engine guarantees cancellation happens before
    /// any ticket reaches a worker.
    #[test]
    fn prop_cancelled_tokens_never_complete() {
        forall(
            0xC0FFEE,
            12,
            |rng| {
                let n = 1 + rng.below(10) as usize;
                (0..n)
                    .map(|_| (rng.below(8), rng.below(2)))
                    .collect::<Vec<(u64, u64)>>()
            },
            |plan| {
                let eng = TransferEngine::new(cfg(2), source(8, Duration::ZERO));
                eng.pause();
                let mut tokens: Vec<(u64, CancelToken, bool)> = Vec::new();
                for &(k, do_cancel) in plan {
                    if let Submit::Queued(tok) = eng.submit(key(k), Lane::Prefetch) {
                        tokens.push((k, tok, do_cancel == 1));
                    }
                }
                for (_, tok, do_cancel) in &tokens {
                    if *do_cancel {
                        tok.cancel();
                    }
                }
                eng.resume();
                if !eng.wait_quiescent(T) {
                    return Err("engine did not quiesce".into());
                }
                let done = eng.drain();
                for (k, _, do_cancel) in &tokens {
                    let got = done.iter().filter(|c| c.key == key(*k)).count();
                    let want = if *do_cancel { 0 } else { 1 };
                    check(
                        got == want,
                        format!("key {k}: {got} completions, want {want} (cancel={do_cancel})"),
                    )?;
                }
                let s = eng.stats();
                let cancelled = tokens.iter().filter(|(_, _, c)| *c).count() as u64;
                check(
                    s.prefetch.cancelled == cancelled,
                    format!("cancelled {} != {}", s.prefetch.cancelled, cancelled),
                )
            },
        );
    }

    /// Satellite: multi-threaded stress over submit/cancel/upgrade
    /// races. Invariant: every accepted ticket resolves exactly once —
    /// completed + cancelled + failed == queued — and the engine
    /// quiesces with no stuck tickets.
    #[test]
    fn stress_submit_cancel_upgrade_races() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let eng = Arc::new(TransferEngine::new(
            IoConfig {
                workers: 4,
                demand_depth: 256,
                prefetch_depth: 256,
                ..IoConfig::default()
            },
            source(32, Duration::from_micros(20)),
        ));
        let queued = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let eng = Arc::clone(&eng);
            let queued = Arc::clone(&queued);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(0xBEEF ^ t);
                for _ in 0..300 {
                    let k = key(rng.below(32));
                    match rng.below(4) {
                        0 => {
                            if matches!(eng.submit(k, Lane::Demand), Submit::Queued(_)) {
                                queued.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        1 | 2 => {
                            if matches!(eng.submit(k, Lane::Prefetch), Submit::Queued(_)) {
                                queued.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        _ => {
                            eng.cancel(k);
                        }
                    }
                    if rng.below(8) == 0 {
                        eng.drain();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(eng.wait_quiescent(T), "stuck tickets");
        eng.drain();
        let s = eng.stats();
        let resolved = s.demand.completed
            + s.demand.cancelled
            + s.demand.failed
            + s.prefetch.completed
            + s.prefetch.cancelled
            + s.prefetch.failed;
        assert_eq!(
            resolved,
            queued.load(Ordering::SeqCst),
            "every accepted ticket must resolve exactly once: {s:?}"
        );
        assert_eq!(s.demand.rejected + s.prefetch.rejected, 0, "depth 256 never fills");
        assert_eq!(eng.queue_depth(Lane::Demand), 0);
        assert_eq!(eng.queue_depth(Lane::Prefetch), 0);
    }

    #[test]
    fn drop_with_queued_work_does_not_hang() {
        let eng = TransferEngine::new(cfg(2), source(16, Duration::from_micros(50)));
        for i in 0..16 {
            eng.submit(key(i), Lane::Prefetch);
        }
        drop(eng); // must join cleanly mid-flight
    }

    /// A source that fails the first `fails[key]` fetches of a key,
    /// then serves it — the transient-error shape the retry loop exists
    /// for.
    fn flaky_source(n_keys: u64, fails: &[(u64, u32)]) -> Arc<dyn FetchSource> {
        struct Flaky {
            store: Mutex<MemStore>,
            fails: Mutex<HashMap<ChunkKey, u32>>,
        }
        impl FetchSource for Flaky {
            fn fetch(&self, k: ChunkKey) -> Result<Option<Vec<u8>>> {
                let mut fails = self.fails.lock().unwrap();
                if let Some(n) = fails.get_mut(&k) {
                    if *n > 0 {
                        *n -= 1;
                        return Err(anyhow!("transient read error"));
                    }
                }
                drop(fails);
                self.store.lock().unwrap().get(k)
            }
        }
        let mut store = MemStore::new();
        for i in 0..n_keys {
            store.put(key(i), &[i as u8; 8]).unwrap();
        }
        Arc::new(Flaky {
            store: Mutex::new(store),
            fails: Mutex::new(fails.iter().map(|&(k, n)| (key(k), n)).collect()),
        })
    }

    #[test]
    fn transient_errors_recover_within_retry_budget() {
        let eng = TransferEngine::new(
            IoConfig { workers: 1, retries: 3, retry_backoff_ms: 0, ..IoConfig::default() },
            flaky_source(4, &[(0, 2)]),
        );
        eng.submit(key(0), Lane::Demand);
        let c = eng.take_blocking(key(0), T).expect("completion");
        assert_eq!(c.data.unwrap(), vec![0u8; 8], "recovered after 2 retries");
        let s = eng.stats();
        assert_eq!(s.demand.retries, 2);
        assert_eq!(s.demand.completed, 1);
        assert_eq!(s.demand.failed, 0);
    }

    /// Satellite: the retry path gives up after the bound and degrades
    /// — the ticket fails (caller recomputes) instead of retrying
    /// forever or crashing.
    #[test]
    fn retry_gives_up_after_bound_and_degrades() {
        let eng = TransferEngine::new(
            IoConfig { workers: 1, retries: 2, retry_backoff_ms: 0, ..IoConfig::default() },
            flaky_source(4, &[(0, 10)]),
        );
        eng.submit(key(0), Lane::Demand);
        let c = eng.take_blocking(key(0), T).expect("completion");
        assert!(c.data.is_err(), "exhausted retries must fail the ticket");
        let s = eng.stats();
        assert_eq!(s.demand.retries, 2, "exactly the bound was spent");
        assert_eq!(s.demand.failed, 1);
        assert_eq!(s.demand.completed, 0);
        // the engine is still healthy: the next read serves normally
        eng.submit(key(1), Lane::Demand);
        let c = eng.take_blocking(key(1), T).expect("completion");
        assert!(c.data.is_ok());
    }

    #[test]
    fn misses_are_not_retried() {
        let eng = TransferEngine::new(
            IoConfig { workers: 1, retries: 3, retry_backoff_ms: 0, ..IoConfig::default() },
            source(1, Duration::ZERO),
        );
        eng.submit(ChunkKey(0xDEAD), Lane::Demand);
        let c = eng.take_blocking(ChunkKey(0xDEAD), T).expect("completion");
        assert!(c.data.is_err());
        assert_eq!(eng.stats().demand.retries, 0, "Ok(None) is definitive");
    }

    #[test]
    fn panicking_source_is_isolated_and_worker_respawned() {
        struct Bomb {
            store: Mutex<MemStore>,
        }
        impl FetchSource for Bomb {
            fn fetch(&self, k: ChunkKey) -> Result<Option<Vec<u8>>> {
                if k == key(13) {
                    panic!("source exploded");
                }
                self.store.lock().unwrap().get(k)
            }
        }
        let mut store = MemStore::new();
        for i in 0..16 {
            store.put(key(i), &[i as u8; 8]).unwrap();
        }
        let eng = TransferEngine::new(
            IoConfig { workers: 1, retries: 0, retry_backoff_ms: 0, ..IoConfig::default() },
            Arc::new(Bomb { store: Mutex::new(store) }),
        );
        eng.submit(key(13), Lane::Demand);
        let c = eng.take_blocking(key(13), T).expect("panicked ticket still resolves");
        assert!(c.data.is_err());
        let s = eng.stats();
        assert_eq!(s.worker_respawns, 1);
        assert_eq!(s.demand.failed, 1);
        // the respawned worker keeps serving
        eng.submit(key(1), Lane::Demand);
        let c = eng.take_blocking(key(1), T).expect("completion after respawn");
        assert_eq!(c.data.unwrap(), vec![1u8; 8]);
        assert!(eng.wait_quiescent(T));
    }
}
