//! The asynchronous [`TransferEngine`]: dual-lane SSD→DRAM chunk reads
//! over `util::threadpool` workers.
//!
//! Design contract (see the module docs of [`crate::io`] for the lane
//! semantics):
//!
//! * `submit` never blocks and never touches disk — it either queues a
//!   ticket, coalesces onto an in-flight one (`Deduped` / `Upgraded`),
//!   or refuses under backpressure (`Rejected`).
//! * Workers drain the demand queue strictly before the prefetch queue
//!   and FIFO within each lane.
//! * At most one in-flight ticket exists per chunk key; a completed or
//!   cancelled ticket frees the key for resubmission.
//! * A cancelled token never produces a completion (checked both before
//!   and after the read, so cancellation racing the read still wins).
//! * Promotion into DRAM is the caller's job: completions carry raw
//!   bytes so cache-metadata mutation stays on the scheduler thread.

use crate::cache::chunk::ChunkKey;
use crate::cache::store::ChunkStore;
use crate::io::token::CancelToken;
use crate::io::{IoConfig, IoStats, Lane};
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Read-side source of chunk bytes, shared with the worker threads.
///
/// Blanket impls cover the repo's stores behind the standard locks:
/// `RwLock<FileStore>` gives concurrent reads (`ChunkStore::get` takes
/// `&self`); `Mutex<S>` serialises and suits tests.
pub trait FetchSource: Send + Sync {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>>;
}

impl<S: ChunkStore + Sync> FetchSource for RwLock<S> {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        self.read().expect("store lock poisoned").get(key)
    }
}

impl<S: ChunkStore> FetchSource for Mutex<S> {
    fn fetch(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        self.lock().expect("store lock poisoned").get(key)
    }
}

/// Outcome of one `submit` call.
#[derive(Debug)]
pub enum Submit {
    /// Accepted; the token cancels this ticket.
    Queued(CancelToken),
    /// The key is already in flight on the same (or demand) lane.
    Deduped,
    /// A demand submit found an in-flight *prefetch* ticket and
    /// promoted it: the chunk will be read once, at demand priority.
    Upgraded,
    /// The lane queue is full (backpressure).
    Rejected,
}

impl Submit {
    pub fn accepted(&self) -> bool {
        !matches!(self, Submit::Rejected)
    }
}

/// One finished (or failed) read, delivered via `drain`/`take_blocking`.
#[derive(Debug)]
pub struct Completion {
    pub key: ChunkKey,
    /// Lane the ticket was *served* on (demand after an upgrade).
    pub lane: Lane,
    /// True iff a prefetch ticket was upgraded to demand priority.
    pub upgraded: bool,
    pub data: Result<Vec<u8>>,
    /// Seconds spent queued before a worker picked the ticket up.
    pub wait_seconds: f64,
    /// Seconds spent reading from the source.
    pub read_seconds: f64,
}

struct Ticket {
    key: ChunkKey,
    enqueued: Instant,
}

struct Entry {
    token: CancelToken,
    lane: Lane,
    upgraded: bool,
}

#[derive(Default)]
struct State {
    demand_q: VecDeque<Ticket>,
    prefetch_q: VecDeque<Ticket>,
    /// One entry per key with a queued or executing ticket.
    inflight: HashMap<ChunkKey, Entry>,
    completions: VecDeque<Completion>,
    stats: IoStats,
    paused: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Signalled when work arrives / pause lifts / shutdown starts.
    work: Condvar,
    /// Signalled when a completion lands or a ticket dies.
    done: Condvar,
}

/// Asynchronous dual-lane chunk mover. See module docs.
pub struct TransferEngine {
    shared: Arc<Shared>,
    cfg: IoConfig,
    // Dropped after the custom Drop body flips `shutdown`, so the
    // pool's join sees exiting workers.
    _pool: ThreadPool,
}

impl TransferEngine {
    pub fn new(cfg: IoConfig, source: Arc<dyn FetchSource>) -> TransferEngine {
        let workers = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let pool = ThreadPool::new(workers, "io");
        for _ in 0..workers {
            let shared = Arc::clone(&shared);
            let source = Arc::clone(&source);
            pool.submit(move || worker_loop(&shared, &*source));
        }
        TransferEngine {
            shared,
            cfg,
            _pool: pool,
        }
    }

    pub fn config(&self) -> IoConfig {
        self.cfg
    }

    /// Queue a read of `key` on `lane`. Non-blocking; see [`Submit`].
    pub fn submit(&self, key: ChunkKey, lane: Lane) -> Submit {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(cur_lane) = st.inflight.get(&key).map(|e| e.lane) {
            if lane == Lane::Demand && cur_lane == Lane::Prefetch {
                // Upgrade: move the queued ticket to the demand lane; a
                // ticket already at a worker keeps running but its
                // completion is re-labelled demand.
                if let Some(pos) = st.prefetch_q.iter().position(|t| t.key == key) {
                    if let Some(t) = st.prefetch_q.remove(pos) {
                        st.demand_q.push_back(t);
                    }
                }
                let e = st.inflight.get_mut(&key).expect("entry just observed");
                e.lane = Lane::Demand;
                e.upgraded = true;
                st.stats.upgraded += 1;
                self.shared.work.notify_all();
                return Submit::Upgraded;
            }
            st.stats.lane_mut(lane).deduped += 1;
            return Submit::Deduped;
        }
        let full = match lane {
            Lane::Demand => st.demand_q.len() >= self.cfg.demand_depth.max(1),
            Lane::Prefetch => st.prefetch_q.len() >= self.cfg.prefetch_depth.max(1),
        };
        if full {
            st.stats.lane_mut(lane).rejected += 1;
            return Submit::Rejected;
        }
        let token = CancelToken::new();
        st.inflight.insert(
            key,
            Entry {
                token: token.clone(),
                lane,
                upgraded: false,
            },
        );
        let ticket = Ticket {
            key,
            enqueued: Instant::now(),
        };
        match lane {
            Lane::Demand => st.demand_q.push_back(ticket),
            Lane::Prefetch => st.prefetch_q.push_back(ticket),
        }
        st.stats.lane_mut(lane).submitted += 1;
        self.shared.work.notify_one();
        Submit::Queued(token)
    }

    /// Cancel the in-flight ticket for `key`, if any. Returns whether a
    /// ticket was found. (Equivalent to cancelling the submit token.)
    pub fn cancel(&self, key: ChunkKey) -> bool {
        let st = self.shared.state.lock().unwrap();
        match st.inflight.get(&key) {
            Some(e) => {
                e.token.cancel();
                true
            }
            None => false,
        }
    }

    /// Stop workers from picking up new tickets (submits still queue).
    /// Used to stage a burst atomically; pair with [`Self::resume`].
    pub fn pause(&self) {
        self.shared.state.lock().unwrap().paused = true;
    }

    pub fn resume(&self) {
        self.shared.state.lock().unwrap().paused = false;
        self.shared.work.notify_all();
    }

    /// Pop every completion delivered so far (the scheduler's per-tick
    /// drain; promotion into DRAM happens at the call site).
    pub fn drain(&self) -> Vec<Completion> {
        let mut st = self.shared.state.lock().unwrap();
        st.completions.drain(..).collect()
    }

    /// Block until the completion for `key` arrives, then take it.
    /// Returns `None` if `key` is neither in flight nor completed (e.g.
    /// never submitted, or cancelled and reaped), or on timeout.
    pub fn take_blocking(&self, key: ChunkKey, timeout: Duration) -> Option<Completion> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(pos) = st.completions.iter().position(|c| c.key == key) {
                return st.completions.remove(pos);
            }
            if !st.inflight.contains_key(&key) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .shared
                .done
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// Busy-poll until no ticket is queued or executing (tests/benches).
    pub fn wait_quiescent(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let st = self.shared.state.lock().unwrap();
                if st.inflight.is_empty() {
                    return true;
                }
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    pub fn stats(&self) -> IoStats {
        self.shared.state.lock().unwrap().stats
    }

    /// Tickets currently queued (not yet picked up) on `lane`.
    pub fn queue_depth(&self, lane: Lane) -> usize {
        let st = self.shared.state.lock().unwrap();
        match lane {
            Lane::Demand => st.demand_q.len(),
            Lane::Prefetch => st.prefetch_q.len(),
        }
    }

    /// Keys with a queued or executing ticket.
    pub fn inflight_count(&self) -> usize {
        self.shared.state.lock().unwrap().inflight.len()
    }

    /// Completions delivered but not yet drained.
    pub fn completed_pending(&self) -> usize {
        self.shared.state.lock().unwrap().completions.len()
    }
}

impl Drop for TransferEngine {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        // `_pool` drops next and joins the exiting workers.
    }
}

fn worker_loop(shared: &Shared, source: &dyn FetchSource) {
    loop {
        // Pop the next ticket: demand first, FIFO within a lane. The
        // cancellation check happens under the same lock, so a ticket
        // observed cancelled here provably never reached the source.
        let (ticket, token, wait_s) = {
            let mut st = shared.state.lock().unwrap();
            'pop: loop {
                if st.shutdown {
                    return;
                }
                if !st.paused {
                    let popped = st
                        .demand_q
                        .pop_front()
                        .or_else(|| st.prefetch_q.pop_front());
                    if let Some(t) = popped {
                        let (lane, token, cancelled) = match st.inflight.get(&t.key) {
                            Some(e) => (e.lane, e.token.clone(), e.token.is_cancelled()),
                            None => continue 'pop, // reaped: stale ticket
                        };
                        if cancelled {
                            st.inflight.remove(&t.key);
                            st.stats.lane_mut(lane).cancelled += 1;
                            shared.done.notify_all();
                            continue 'pop;
                        }
                        let wait = t.enqueued.elapsed().as_secs_f64();
                        break 'pop (t, token, wait);
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };

        let t0 = Instant::now();
        let fetched = source.fetch(ticket.key);
        let read_s = t0.elapsed().as_secs_f64();

        let mut st = shared.state.lock().unwrap();
        let entry = match st.inflight.remove(&ticket.key) {
            Some(e) => e,
            None => continue,
        };
        if token.is_cancelled() {
            // Cancel raced the read: suppress the completion.
            st.stats.lane_mut(entry.lane).cancelled += 1;
            shared.done.notify_all();
            continue;
        }
        let lane = entry.lane;
        let data = {
            let s = st.stats.lane_mut(lane);
            s.wait_seconds += wait_s;
            s.serve_seconds += read_s;
            match fetched {
                Ok(Some(bytes)) => {
                    s.completed += 1;
                    s.bytes_moved += bytes.len() as u64;
                    Ok(bytes)
                }
                Ok(None) => {
                    s.failed += 1;
                    Err(anyhow!("chunk {:016x} missing from source", ticket.key.0))
                }
                Err(e) => {
                    s.failed += 1;
                    Err(e)
                }
            }
        };
        st.completions.push_back(Completion {
            key: ticket.key,
            lane,
            upgraded: entry.upgraded,
            data,
            wait_seconds: wait_s,
            read_seconds: read_s,
        });
        shared.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::store::MemStore;
    use crate::util::proptest::{check, forall};

    fn key(i: u64) -> ChunkKey {
        ChunkKey(0x1000 + i)
    }

    /// A MemStore-backed source with optional per-read delay.
    fn source(n_keys: u64, delay: Duration) -> Arc<dyn FetchSource> {
        struct Slow {
            store: Mutex<MemStore>,
            delay: Duration,
        }
        impl FetchSource for Slow {
            fn fetch(&self, k: ChunkKey) -> Result<Option<Vec<u8>>> {
                if !self.delay.is_zero() {
                    std::thread::sleep(self.delay);
                }
                self.store.lock().unwrap().get(k)
            }
        }
        let mut store = MemStore::new();
        for i in 0..n_keys {
            store.put(key(i), &[i as u8; 8]).unwrap();
        }
        Arc::new(Slow {
            store: Mutex::new(store),
            delay,
        })
    }

    fn cfg(workers: usize) -> IoConfig {
        IoConfig {
            workers,
            demand_depth: 64,
            prefetch_depth: 64,
        }
    }

    const T: Duration = Duration::from_secs(10);

    #[test]
    fn demand_preempts_queued_prefetch_and_lanes_stay_fifo() {
        let eng = TransferEngine::new(cfg(1), source(16, Duration::ZERO));
        eng.pause();
        for i in 0..4 {
            assert!(matches!(eng.submit(key(i), Lane::Prefetch), Submit::Queued(_)));
        }
        for i in 4..6 {
            assert!(matches!(eng.submit(key(i), Lane::Demand), Submit::Queued(_)));
        }
        eng.resume();
        assert!(eng.wait_quiescent(T));
        let done = eng.drain();
        let order: Vec<u64> = done.iter().map(|c| c.key.0 - 0x1000).collect();
        // demand (FIFO) first, then prefetch (FIFO)
        assert_eq!(order, vec![4, 5, 0, 1, 2, 3]);
        assert!(done[0].lane == Lane::Demand && done[2].lane == Lane::Prefetch);
        let s = eng.stats();
        assert_eq!(s.demand.completed, 2);
        assert_eq!(s.prefetch.completed, 4);
        assert_eq!(s.demand.bytes_moved, 16);
    }

    #[test]
    fn demand_upgrade_serves_once() {
        let eng = TransferEngine::new(cfg(2), source(8, Duration::ZERO));
        eng.pause();
        assert!(matches!(eng.submit(key(3), Lane::Prefetch), Submit::Queued(_)));
        assert!(matches!(eng.submit(key(3), Lane::Demand), Submit::Upgraded));
        // further demand submits coalesce
        assert!(matches!(eng.submit(key(3), Lane::Demand), Submit::Deduped));
        eng.resume();
        let c = eng.take_blocking(key(3), T).expect("completion");
        assert_eq!(c.lane, Lane::Demand);
        assert!(c.upgraded);
        assert_eq!(c.data.unwrap(), vec![3u8; 8]);
        // exactly one completion existed for the key
        assert!(eng.drain().is_empty());
        let s = eng.stats();
        assert_eq!(s.upgraded, 1);
        assert_eq!(s.demand.deduped, 1);
        assert_eq!(s.prefetch.submitted, 1);
        assert_eq!(s.demand.submitted, 0);
        // key is free again after completion
        assert!(matches!(eng.submit(key(3), Lane::Demand), Submit::Queued(_)));
        assert!(eng.take_blocking(key(3), T).is_some());
    }

    #[test]
    fn duplicate_prefetch_submits_dedup() {
        let eng = TransferEngine::new(cfg(1), source(4, Duration::ZERO));
        eng.pause();
        assert!(matches!(eng.submit(key(0), Lane::Prefetch), Submit::Queued(_)));
        assert!(matches!(eng.submit(key(0), Lane::Prefetch), Submit::Deduped));
        eng.resume();
        assert!(eng.wait_quiescent(T));
        assert_eq!(eng.drain().len(), 1);
        assert_eq!(eng.stats().prefetch.deduped, 1);
    }

    #[test]
    fn backpressure_rejects_when_lane_full() {
        let eng = TransferEngine::new(
            IoConfig {
                workers: 1,
                demand_depth: 64,
                prefetch_depth: 2,
            },
            source(8, Duration::ZERO),
        );
        eng.pause();
        assert!(eng.submit(key(0), Lane::Prefetch).accepted());
        assert!(eng.submit(key(1), Lane::Prefetch).accepted());
        assert!(matches!(eng.submit(key(2), Lane::Prefetch), Submit::Rejected));
        assert!(matches!(eng.submit(key(3), Lane::Prefetch), Submit::Rejected));
        eng.resume();
        assert!(eng.wait_quiescent(T));
        assert_eq!(eng.stats().prefetch.rejected, 2);
        assert_eq!(eng.drain().len(), 2);
    }

    #[test]
    fn missing_key_fails_but_completes() {
        let eng = TransferEngine::new(cfg(1), source(1, Duration::ZERO));
        eng.submit(ChunkKey(0xDEAD), Lane::Demand);
        let c = eng.take_blocking(ChunkKey(0xDEAD), T).expect("completion");
        assert!(c.data.is_err());
        assert_eq!(eng.stats().demand.failed, 1);
        assert_eq!(eng.stats().demand.completed, 0);
    }

    #[test]
    fn cancelled_ticket_is_reaped_without_completion() {
        let eng = TransferEngine::new(cfg(1), source(4, Duration::ZERO));
        eng.pause();
        let tok = match eng.submit(key(1), Lane::Prefetch) {
            Submit::Queued(t) => t,
            other => panic!("{other:?}"),
        };
        eng.submit(key(2), Lane::Prefetch);
        tok.cancel();
        eng.resume();
        assert!(eng.wait_quiescent(T));
        let done = eng.drain();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].key, key(2));
        assert_eq!(eng.stats().prefetch.cancelled, 1);
        // take_blocking on the reaped key returns None, promptly
        assert!(eng.take_blocking(key(1), Duration::from_millis(50)).is_none());
    }

    #[test]
    fn cancel_by_key_matches_token_cancel() {
        let eng = TransferEngine::new(cfg(1), source(4, Duration::ZERO));
        eng.pause();
        eng.submit(key(0), Lane::Prefetch);
        assert!(eng.cancel(key(0)));
        assert!(!eng.cancel(key(3))); // nothing in flight
        eng.resume();
        assert!(eng.wait_quiescent(T));
        assert!(eng.drain().is_empty());
        assert_eq!(eng.stats().prefetch.cancelled, 1);
    }

    /// Satellite: property — no completion is ever delivered for a
    /// cancelled token, and every surviving submit completes exactly
    /// once. Pausing the engine guarantees cancellation happens before
    /// any ticket reaches a worker.
    #[test]
    fn prop_cancelled_tokens_never_complete() {
        forall(
            0xC0FFEE,
            12,
            |rng| {
                let n = 1 + rng.below(10) as usize;
                (0..n)
                    .map(|_| (rng.below(8), rng.below(2)))
                    .collect::<Vec<(u64, u64)>>()
            },
            |plan| {
                let eng = TransferEngine::new(cfg(2), source(8, Duration::ZERO));
                eng.pause();
                let mut tokens: Vec<(u64, CancelToken, bool)> = Vec::new();
                for &(k, do_cancel) in plan {
                    if let Submit::Queued(tok) = eng.submit(key(k), Lane::Prefetch) {
                        tokens.push((k, tok, do_cancel == 1));
                    }
                }
                for (_, tok, do_cancel) in &tokens {
                    if *do_cancel {
                        tok.cancel();
                    }
                }
                eng.resume();
                if !eng.wait_quiescent(T) {
                    return Err("engine did not quiesce".into());
                }
                let done = eng.drain();
                for (k, _, do_cancel) in &tokens {
                    let got = done.iter().filter(|c| c.key == key(*k)).count();
                    let want = if *do_cancel { 0 } else { 1 };
                    check(
                        got == want,
                        format!("key {k}: {got} completions, want {want} (cancel={do_cancel})"),
                    )?;
                }
                let s = eng.stats();
                let cancelled = tokens.iter().filter(|(_, _, c)| *c).count() as u64;
                check(
                    s.prefetch.cancelled == cancelled,
                    format!("cancelled {} != {}", s.prefetch.cancelled, cancelled),
                )
            },
        );
    }

    /// Satellite: multi-threaded stress over submit/cancel/upgrade
    /// races. Invariant: every accepted ticket resolves exactly once —
    /// completed + cancelled + failed == queued — and the engine
    /// quiesces with no stuck tickets.
    #[test]
    fn stress_submit_cancel_upgrade_races() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let eng = Arc::new(TransferEngine::new(
            IoConfig {
                workers: 4,
                demand_depth: 256,
                prefetch_depth: 256,
            },
            source(32, Duration::from_micros(20)),
        ));
        let queued = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let eng = Arc::clone(&eng);
            let queued = Arc::clone(&queued);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(0xBEEF ^ t);
                for _ in 0..300 {
                    let k = key(rng.below(32));
                    match rng.below(4) {
                        0 => {
                            if matches!(eng.submit(k, Lane::Demand), Submit::Queued(_)) {
                                queued.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        1 | 2 => {
                            if matches!(eng.submit(k, Lane::Prefetch), Submit::Queued(_)) {
                                queued.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        _ => {
                            eng.cancel(k);
                        }
                    }
                    if rng.below(8) == 0 {
                        eng.drain();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(eng.wait_quiescent(T), "stuck tickets");
        eng.drain();
        let s = eng.stats();
        let resolved = s.demand.completed
            + s.demand.cancelled
            + s.demand.failed
            + s.prefetch.completed
            + s.prefetch.cancelled
            + s.prefetch.failed;
        assert_eq!(
            resolved,
            queued.load(Ordering::SeqCst),
            "every accepted ticket must resolve exactly once: {s:?}"
        );
        assert_eq!(s.demand.rejected + s.prefetch.rejected, 0, "depth 256 never fills");
        assert_eq!(eng.queue_depth(Lane::Demand), 0);
        assert_eq!(eng.queue_depth(Lane::Prefetch), 0);
    }

    #[test]
    fn drop_with_queued_work_does_not_hang() {
        let eng = TransferEngine::new(cfg(2), source(16, Duration::from_micros(50)));
        for i in 0..16 {
            eng.submit(key(i), Lane::Prefetch);
        }
        drop(eng); // must join cleanly mid-flight
    }
}
