//! The tiered-transfer I/O subsystem: asynchronous SSD↔DRAM chunk
//! movement with **dual priority lanes**, in-flight dedup, cancellation,
//! and backpressure (paper §4.3/§4.4 made real — the counterpart of the
//! simulator's virtual-time channels on actual disk).
//!
//! # Architecture
//!
//! * [`engine`] — the real-path [`TransferEngine`](engine::TransferEngine):
//!   `util::threadpool` workers pull read tickets from two bounded
//!   queues and fetch chunk bytes from a shared
//!   [`FetchSource`](engine::FetchSource) (e.g. the SSD
//!   [`FileStore`](crate::cache::store::FileStore)). Completed reads
//!   land in a completion queue the scheduler drains each tick;
//!   promotion into DRAM stays on the caller's thread because the cache
//!   metadata engine is single-threaded by design.
//! * [`lanes`] — the same dual-lane semantics as a virtual-time cost
//!   model ([`VirtualLanes`](lanes::VirtualLanes)), used by
//!   `serve::engine` so the simulator and the real path share one
//!   contention vocabulary (and one [`IoStats`] report shape).
//! * [`token`] — [`CancelToken`](token::CancelToken): lazy cancellation
//!   observed by workers before (and re-checked after) the disk read.
//!
//! # Lane semantics
//!
//! * **Demand lane** — chunks the request being scheduled needs *now*.
//!   Workers always drain the demand queue first: a demand ticket never
//!   waits behind queued prefetch work (it can still wait for reads
//!   already on the device — preemption is at queue granularity).
//! * **Prefetch lane** — speculative SSD→DRAM promotions selected from
//!   the waiting queue's look-ahead window. Served only when the demand
//!   queue is empty, so a prefetch backlog cannot inflate TTFT — the
//!   Fig 12 trade-off the paper's bounded window manages.
//! * **Dedup / upgrade** — at most one in-flight ticket per chunk key.
//!   Re-submitting an in-flight key is counted `deduped`; a *demand*
//!   submit for a key that is in flight on the *prefetch* lane upgrades
//!   that ticket in place (moves it to the demand queue if still
//!   queued), so the chunk is read **once** and served at demand
//!   priority — counted `upgraded`.
//! * **Backpressure** — both queues are bounded
//!   ([`IoConfig::demand_depth`] / [`IoConfig::prefetch_depth`]);
//!   submits beyond the bound are rejected and counted, never silently
//!   dropped or unboundedly buffered.
//!
//! Configured via the `[io]` TOML section (`io.workers`,
//! `io.demand_depth`, `io.prefetch_depth`, `io.retries`,
//! `io.retry_backoff_ms`) — see [`crate::config::ExperimentConfig`].
//!
//! # Failure model & degradation matrix
//!
//! The storage hierarchy is **best-effort acceleration over an
//! always-correct recompute path**: no fault below the cache boundary
//! may fail a request, only slow it down. The [`fault`] module is the
//! seeded injection harness that proves this, and the chaos proptest
//! in `serve::engine` holds the headline invariant: under *any* fault
//! plan every request completes with output identical to the
//! fault-free run, and the degradation counters
//! ([`crate::serve::metrics::DegradeStats`]) account for every
//! injection.
//!
//! | Fault | Detection | Response | Counters |
//! |---|---|---|---|
//! | Transient read error | `FetchSource::fetch` returns `Err` | retried up to [`IoConfig::retries`] times with exponential backoff ([`IoConfig::retry_backoff_ms`] × 2ⁿ); recovery is invisible beyond latency | `LaneStats::retries` |
//! | Retries exhausted | still `Err` after the bound | ticket fails → caller degrades to recompute; the copy is quarantined (evicted) | `retries`, `degraded_loads`, `quarantined_chunks` |
//! | Permanent loss | read misses (`Ok(None)`) despite index metadata | never retried (a miss is definitive); quarantine + recompute | `degraded_loads`, `quarantined_chunks`, `StoreStats::lost_files` |
//! | Corruption | fxhash checksum trailer mismatch on `FileStore::get`/restart reconcile | bad copy swept from disk + evicted from the tree; recompute rewrites a clean copy | `degraded_loads`, `quarantined_chunks`, `StoreStats::checksum_failures` |
//! | Latency spike | n/a (indistinguishable from a slow disk) | absorbed; TTFT takes the hit | — |
//! | Worker panic | `catch_unwind` in the worker shell | in-flight ticket fails (caller recomputes), worker respawns, poisoned locks recover | `IoStats::worker_respawns`, lane `failed` |
//! | fsync / delete errors | `FileStore` put/delete syscalls | logged in store stats; never fatal (the payload write itself failing fails the put) | `StoreStats::fsync_errors` / `delete_errors` |
//! | Replica failure | cluster: kill switch / health flag | replica stops receiving routed traffic, its directory holder bits clear, queued+decoding requests re-route and restart | `failovers` |
//!
//! Fatal (by design): nothing on the read path. Write-path errors on
//! `put` still fail the insert — a chunk that was never durably stored
//! must not be indexed as reusable.

pub mod engine;
pub mod fault;
pub mod lanes;
pub mod token;

pub use engine::{Completion, FetchSource, Submit, TransferEngine};
pub use fault::{FaultPlan, FaultSession, FaultyStore, FaultySource, Injected, Transient};
pub use lanes::VirtualLanes;
pub use token::CancelToken;

/// The two transfer priority classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Blocking the request being scheduled; always served first.
    Demand,
    /// Speculative look-ahead work; served when the demand lane is idle.
    Prefetch,
}

impl Lane {
    pub fn name(&self) -> &'static str {
        match self {
            Lane::Demand => "demand",
            Lane::Prefetch => "prefetch",
        }
    }
}

/// Sizing of the transfer engine (the `[io]` config section).
#[derive(Clone, Copy, Debug)]
pub struct IoConfig {
    /// Dedicated I/O worker threads (paper: "dedicated thread" design).
    pub workers: usize,
    /// Bound on queued demand tickets before submits are rejected.
    pub demand_depth: usize,
    /// Bound on queued prefetch tickets before submits are rejected.
    pub prefetch_depth: usize,
    /// Times a read that errors is retried before the ticket fails
    /// (attempts = 1 + retries). Misses are never retried.
    pub retries: u32,
    /// Base backoff between retry attempts, doubled per attempt.
    pub retry_backoff_ms: u64,
}

impl Default for IoConfig {
    fn default() -> IoConfig {
        IoConfig {
            workers: 2,
            demand_depth: 64,
            prefetch_depth: 64,
            retries: 2,
            retry_backoff_ms: 1,
        }
    }
}

/// Counters for one lane. All monotonically non-decreasing over a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneStats {
    /// Tickets accepted into the queue.
    pub submitted: u64,
    /// Reads finished and delivered as completions.
    pub completed: u64,
    /// Tickets dropped because their token was cancelled.
    pub cancelled: u64,
    /// Submits coalesced onto an already-in-flight ticket for the key.
    pub deduped: u64,
    /// Submits refused because the lane queue was full (backpressure).
    pub rejected: u64,
    /// Reads that errored or found the key missing.
    pub failed: u64,
    /// Retry attempts performed after transient read errors (spent
    /// whether or not the read eventually recovered).
    pub retries: u64,
    /// Payload bytes delivered.
    pub bytes_moved: u64,
    /// Total seconds tickets spent queued before a worker picked them up.
    pub wait_seconds: f64,
    /// Total seconds spent actually reading.
    pub serve_seconds: f64,
}

impl LaneStats {
    /// Mean queue wait per completed read (0 if none completed).
    pub fn mean_wait(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.wait_seconds / self.completed as f64
        }
    }

    /// Mean read time per completed read (0 if none completed).
    pub fn mean_serve(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.serve_seconds / self.completed as f64
        }
    }

    /// Sum another lane's counters into this one (cluster aggregation).
    pub fn absorb(&mut self, other: &LaneStats) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.cancelled += other.cancelled;
        self.deduped += other.deduped;
        self.rejected += other.rejected;
        self.failed += other.failed;
        self.retries += other.retries;
        self.bytes_moved += other.bytes_moved;
        self.wait_seconds += other.wait_seconds;
        self.serve_seconds += other.serve_seconds;
    }
}

/// Snapshot of both lanes plus cross-lane events.
#[derive(Clone, Copy, Debug, Default)]
pub struct IoStats {
    pub demand: LaneStats,
    pub prefetch: LaneStats,
    /// Prefetch tickets promoted to demand priority (read once, served
    /// at demand priority instead of being re-read).
    pub upgraded: u64,
    /// I/O workers respawned after a panic escaped the source
    /// (panic-isolation: the engine survives, the ticket fails).
    pub worker_respawns: u64,
}

impl IoStats {
    pub fn lane(&self, lane: Lane) -> &LaneStats {
        match lane {
            Lane::Demand => &self.demand,
            Lane::Prefetch => &self.prefetch,
        }
    }

    pub fn lane_mut(&mut self, lane: Lane) -> &mut LaneStats {
        match lane {
            Lane::Demand => &mut self.demand,
            Lane::Prefetch => &mut self.prefetch,
        }
    }

    /// Sum another snapshot's counters into this one — the cluster
    /// path folds per-replica lane traffic into one fleet total.
    pub fn absorb(&mut self, other: &IoStats) {
        self.demand.absorb(&other.demand);
        self.prefetch.absorb(&other.prefetch);
        self.upgraded += other.upgraded;
        self.worker_respawns += other.worker_respawns;
    }

    /// Two-line human-readable block (mirrors `Report::pretty` rows).
    pub fn pretty(&self) -> String {
        let row = |name: &str, s: &LaneStats| {
            format!(
                "{name} sub={} done={} cancel={} dedup={} reject={} fail={} retry={} \
                 bytes={} wait={:.4}s serve={:.4}s",
                s.submitted,
                s.completed,
                s.cancelled,
                s.deduped,
                s.rejected,
                s.failed,
                s.retries,
                s.bytes_moved,
                s.wait_seconds,
                s.serve_seconds,
            )
        };
        format!(
            "{}\n  {} upgraded={} respawns={}",
            row("demand  ", &self.demand),
            row("prefetch", &self.prefetch),
            self.upgraded,
            self.worker_respawns
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_accessors_agree() {
        let mut s = IoStats::default();
        s.lane_mut(Lane::Demand).submitted = 3;
        s.lane_mut(Lane::Prefetch).rejected = 2;
        assert_eq!(s.lane(Lane::Demand).submitted, 3);
        assert_eq!(s.lane(Lane::Prefetch).rejected, 2);
        assert_eq!(Lane::Demand.name(), "demand");
    }

    #[test]
    fn mean_times_guard_division() {
        let mut s = LaneStats::default();
        assert_eq!(s.mean_wait(), 0.0);
        s.completed = 4;
        s.wait_seconds = 2.0;
        s.serve_seconds = 1.0;
        assert!((s.mean_wait() - 0.5).abs() < 1e-12);
        assert!((s.mean_serve() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn pretty_mentions_both_lanes() {
        let s = IoStats::default();
        let p = s.pretty();
        assert!(p.contains("demand"));
        assert!(p.contains("prefetch"));
        assert!(p.contains("upgraded"));
    }
}
