//! Virtual-time dual-lane model of one shared bandwidth resource.
//!
//! [`VirtualLanes`] gives the serving *simulator* the same lane
//! semantics the real [`TransferEngine`](crate::io::engine::TransferEngine)
//! enforces with worker threads: demand transfers never wait behind
//! **queued** prefetch work (strict priority at queue granularity),
//! while prefetch transfers wait behind everything. Both lanes draw on
//! one bandwidth figure, so saturating the prefetch lane still delays
//! later prefetches — the Fig 12 contention — but cannot inflate the
//! demand lane.
//!
//! Accounting note: in virtual time a transfer's finish is known at
//! enqueue, so `submitted`/`bytes_moved`/`wait`/`serve` are booked at
//! enqueue; `completed` is booked by the caller when it acts on the
//! finish time (the prefetcher's drain, or the demand path awaiting
//! `ssd_ready`), and `cancelled` when a not-yet-started transfer is
//! abandoned.

use crate::hw::transfer::Channel;
use crate::io::{IoStats, Lane};

/// Two priority cursors over one virtual-time bandwidth resource.
#[derive(Clone, Debug)]
pub struct VirtualLanes {
    pub bytes_per_s: f64,
    pub launch_overhead_s: f64,
    demand_free_at: f64,
    prefetch_free_at: f64,
    /// Lane counters, shared shape with the real engine's report.
    pub stats: IoStats,
}

impl VirtualLanes {
    pub fn new(gbps: f64, launch_overhead_s: f64) -> VirtualLanes {
        VirtualLanes {
            bytes_per_s: gbps * 1e9,
            launch_overhead_s,
            demand_free_at: 0.0,
            prefetch_free_at: 0.0,
            stats: IoStats::default(),
        }
    }

    /// Adopt the bandwidth/overhead of an existing fabric channel.
    pub fn from_channel(ch: &Channel) -> VirtualLanes {
        VirtualLanes {
            bytes_per_s: ch.bytes_per_s,
            launch_overhead_s: ch.launch_overhead_s,
            demand_free_at: 0.0,
            prefetch_free_at: 0.0,
            stats: IoStats::default(),
        }
    }

    /// Pure cost of one transfer of `bytes` (no queueing).
    pub fn copy_time(&self, bytes: u64) -> f64 {
        self.launch_overhead_s + bytes as f64 / self.bytes_per_s
    }

    /// Advance the lane cursors for one transfer submitted at `now`
    /// without touching counters (used for in-place lane upgrades).
    /// Returns `(start, finish)`.
    pub fn reserve(&mut self, lane: Lane, now: f64, bytes: u64) -> (f64, f64) {
        let cost = self.copy_time(bytes);
        match lane {
            Lane::Demand => {
                // Demand bypasses queued prefetch work entirely; it only
                // queues behind other demand transfers.
                let start = now.max(self.demand_free_at);
                let finish = start + cost;
                self.demand_free_at = finish;
                // The shared resource is busy: queued prefetch work is
                // pushed back behind the demand transfer.
                self.prefetch_free_at = self.prefetch_free_at.max(finish);
                (start, finish)
            }
            Lane::Prefetch => {
                let start = now.max(self.prefetch_free_at).max(self.demand_free_at);
                let finish = start + cost;
                self.prefetch_free_at = finish;
                (start, finish)
            }
        }
    }

    /// Enqueue a transfer at `now`: cursor math plus lane accounting.
    /// Returns `(start, finish)`.
    pub fn enqueue(&mut self, lane: Lane, now: f64, bytes: u64) -> (f64, f64) {
        let (start, finish) = self.reserve(lane, now, bytes);
        let s = self.stats.lane_mut(lane);
        s.submitted += 1;
        s.bytes_moved += bytes;
        s.wait_seconds += start - now;
        s.serve_seconds += finish - start;
        (start, finish)
    }

    /// Seconds of committed work beyond `now` on `lane`.
    pub fn backlog(&self, lane: Lane, now: f64) -> f64 {
        let free_at = match lane {
            Lane::Demand => self.demand_free_at,
            Lane::Prefetch => self.prefetch_free_at,
        };
        (free_at - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lanes() -> VirtualLanes {
        VirtualLanes::new(1.0, 0.0) // 1 GB/s, no launch overhead
    }

    const GB: u64 = 1_000_000_000;

    #[test]
    fn demand_bypasses_queued_prefetch_backlog() {
        let mut l = lanes();
        // 3 s of prefetch backlog...
        for _ in 0..3 {
            l.enqueue(Lane::Prefetch, 0.0, GB);
        }
        assert!((l.backlog(Lane::Prefetch, 0.0) - 3.0).abs() < 1e-9);
        // ...yet a demand read at t=0 starts immediately
        let (s, f) = l.enqueue(Lane::Demand, 0.0, GB);
        assert_eq!(s, 0.0);
        assert!((f - 1.0).abs() < 1e-9);
        // and pushes the queued prefetch work back behind it
        let (_, pf) = l.enqueue(Lane::Prefetch, 0.0, GB);
        assert!(pf >= 4.0 - 1e-9, "prefetch finish {pf} must trail backlog + demand");
    }

    #[test]
    fn prefetch_waits_behind_demand() {
        let mut l = lanes();
        l.enqueue(Lane::Demand, 0.0, 2 * GB); // busy until t=2
        let (s, f) = l.enqueue(Lane::Prefetch, 0.0, GB);
        assert!((s - 2.0).abs() < 1e-9);
        assert!((f - 3.0).abs() < 1e-9);
    }

    #[test]
    fn lanes_are_fifo_internally() {
        let mut l = lanes();
        let (_, f1) = l.enqueue(Lane::Prefetch, 0.0, GB);
        let (s2, f2) = l.enqueue(Lane::Prefetch, 0.5, GB);
        assert!((s2 - f1).abs() < 1e-9);
        assert!((f2 - 2.0).abs() < 1e-9);
        let (s3, _) = l.enqueue(Lane::Prefetch, 10.0, GB); // idle resumes at now
        assert_eq!(s3, 10.0);
    }

    #[test]
    fn accounting_books_at_enqueue() {
        let mut l = lanes();
        l.enqueue(Lane::Prefetch, 0.0, GB);
        l.enqueue(Lane::Prefetch, 0.0, GB); // waits 1s
        l.enqueue(Lane::Demand, 0.0, GB);
        let st = l.stats;
        assert_eq!(st.prefetch.submitted, 2);
        assert_eq!(st.demand.submitted, 1);
        assert_eq!(st.prefetch.bytes_moved, 2 * GB);
        assert!((st.prefetch.wait_seconds - 1.0).abs() < 1e-9);
        assert!((st.prefetch.serve_seconds - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reserve_skips_counters() {
        let mut l = lanes();
        l.reserve(Lane::Demand, 0.0, GB);
        assert_eq!(l.stats.demand.submitted, 0);
        assert!((l.backlog(Lane::Demand, 0.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn from_channel_copies_rate() {
        let ch = Channel::new("t", 3.0, 10e-6);
        let l = VirtualLanes::from_channel(&ch);
        assert_eq!(l.bytes_per_s, 3.0e9);
        assert!((l.copy_time(3 * GB) - (1.0 + 10e-6)).abs() < 1e-9);
    }
}
