//! The Cache Engine — the paper's core contribution (§4.2, Fig 6/7).
//!
//! * [`chunk`] — prefix-chain hashed chunk identity (`HashPrefix`).
//! * [`prefix_tree`] — the chunk tree with per-tier residency, the
//!   chain-presence / leaf-only-eviction invariants, and the
//!   policy-owned per-node metadata slot (`Node::policy_meta`).
//! * [`policy`] — the open [`EvictionPolicy`](policy::EvictionPolicy)
//!   trait + name registry: LRU, **look-ahead LRU** (the paper's
//!   contribution), FIFO, PGDSF (RAGCache baseline), SLRU, 2Q, LFUDA
//!   and a look-ahead-SLRU hybrid.
//! * [`prefetch`] — the open
//!   [`PrefetchStrategy`](prefetch::PrefetchStrategy) trait + registry:
//!   `none`, `queue-window` (the paper's §4.4), `depth-bounded[:N]`.
//! * [`tier`] — GPU/DRAM/SSD tiers and byte accounting.
//! * [`engine`] — lookup/insert/promote/evict + prefetch target
//!   selection over the tree.
//! * [`victim_index`] — per-tier lazy rank heaps behind the amortized
//!   O(log n) indexed eviction path (§Perf iteration 3).
//! * [`store`] — actual chunk byte storage for the real PJRT path
//!   (memory + spill-directory backends).
//!
//! # Writing a custom eviction policy
//!
//! Eviction is an open extension point: implement
//! [`policy::EvictionPolicy`] and either register a name (add an arm in
//! `policy::registry::parse` plus an entry in `registry::NAMES` so it
//! becomes reachable from TOML/CLI config and the ablation sweeps) or
//! hand an instance straight to
//! [`engine::CacheEngine::with_policy`]. The contract:
//!
//! * **`rank`** is the only required method: map an evictable candidate
//!   to a [`policy::VictimRank`] — the minimum `(class, score, tie)`
//!   (tie-broken by `NodeId`) is evicted first. Deriving both
//!   `pick_victim` (candidate list) and `pick_victim_fused` (single
//!   allocation-free slab scan) from `rank` makes the two victim paths
//!   agree by construction; if you override them instead, keep them
//!   consistent — the test suite property-checks that parity for every
//!   registered policy.
//! * **Lifecycle hooks** (`on_insert`, `on_hit`, `on_evict`) fire from
//!   the engine after its own bookkeeping. Per-chunk state lives in the
//!   tree's `policy_meta` slot (a `u64` the tree never interprets);
//!   policy-global state lives in your struct's fields.
//!
//! SLRU, condensed from `policy.rs`, shows the whole pattern — one
//! segment bit in `policy_meta`, probation evicts before protected:
//!
//! ```ignore
//! #[derive(Debug, Default)]
//! struct Slru;
//!
//! impl EvictionPolicy for Slru {
//!     fn name(&self) -> &'static str { "slru" }
//!
//!     fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
//!         let n = tree.node(id);
//!         // class 0 = probationary, 1 = protected; LRU within each
//!         VictimRank::classed((n.policy_meta & 1) as u8, n.last_access)
//!     }
//!
//!     fn on_insert(&mut self, tree: &mut PrefixTree, id: NodeId) {
//!         tree.set_policy_meta(id, 0); // enter on probation
//!     }
//!     fn on_hit(&mut self, tree: &mut PrefixTree, id: NodeId) {
//!         tree.set_policy_meta(id, 1); // reuse earns protection
//!     }
//!     fn on_evict(&mut self, tree: &mut PrefixTree, id: NodeId) {
//!         tree.set_policy_meta(id, 0); // survivors re-earn it
//!     }
//! }
//!
//! // Unregistered use:
//! let engine = CacheEngine::with_policy(config, Box::new(Slru));
//! ```
//!
//! ## When a policy must force re-indexing
//!
//! The hot eviction path does not rescan the tree: the engine keeps a
//! [`victim_index::VictimIndex`] of generation-stamped rank entries,
//! and every tree event that can change a rank (touch, boost,
//! `set_policy_meta`, pin/unpin, residency and `present_children`
//! changes) invalidates the affected entries automatically. A custom
//! policy gets the indexed path for free **iff** its `rank` is a pure
//! function of those tracked inputs. Clock dependence is allowed only
//! through `boost_until > tree.now()` comparisons — that one flip is
//! covered by the tree's boost-expiry queue (`expire_boosts`).
//!
//! If your ranks depend on anything else — say, a policy-global knob
//! read inside `rank` (LFUDA's `age` is safe: it feeds ranks only via
//! `policy_meta` writes, which the tree tracks) — you have two options:
//!
//! * override `indexable()` to return `false`: the engine quietly falls
//!   back to the fused scan for this policy; or
//! * keep the index but call
//!   [`engine::CacheEngine::force_reindex`] after every out-of-band
//!   change (it drops the heaps and lazily re-ranks all live nodes).
//!
//! Getting this wrong does not corrupt the tree — it makes victim
//! selection disagree with the fused oracle, which the three-way
//! parity proptest (`prop_indexed_fused_unfused_victim_parity`)
//! catches for registered policies.
//!
//! Prefetch-target selection follows the same shape: implement
//! [`prefetch::PrefetchStrategy::select_targets`] over the waiting
//! queue's look-ahead window and register it in
//! `prefetch::registry::parse`.

pub mod chunk;
pub mod engine;
pub mod policy;
pub mod prefetch;
pub mod prefix_tree;
pub mod store;
pub mod tier;
pub mod victim_index;
