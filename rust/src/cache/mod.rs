//! The Cache Engine — the paper's core contribution (§4.2, Fig 6/7).
//!
//! * [`chunk`] — prefix-chain hashed chunk identity (`HashPrefix`).
//! * [`prefix_tree`] — the chunk tree with per-tier residency and the
//!   chain-presence / leaf-only-eviction invariants.
//! * [`policy`] — LRU, **look-ahead LRU** (the contribution), FIFO and
//!   PGDSF (RAGCache-baseline) eviction.
//! * [`tier`] — GPU/DRAM/SSD tiers and byte accounting.
//! * [`engine`] — lookup/insert/promote/evict + prefetch target
//!   selection over the tree.
//! * [`store`] — actual chunk byte storage for the real PJRT path
//!   (memory + spill-directory backends).

pub mod chunk;
pub mod engine;
pub mod policy;
pub mod prefix_tree;
pub mod store;
pub mod tier;
