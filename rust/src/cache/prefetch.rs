//! Pluggable prefetch-target selection (paper §4.4).
//!
//! The serving loop watches the waiting queue's look-ahead window and
//! asks a [`PrefetchStrategy`] which SSD-resident chunks are worth
//! promoting to DRAM ahead of demand; the mover (`serve::prefetcher`)
//! then owns channel bookkeeping and completion draining. Strategies
//! are an open trait + name-based [`registry`], mirroring
//! `cache::policy`:
//!
//! * `none` — prefetch disabled (the vLLM/CCache/SCCache baselines).
//! * `queue-window` — the paper's strategy: every SSD-only chunk of
//!   every queued request in the window, walked farthest-first so the
//!   soonest request's demand loads queue behind the fewest strangers.
//! * `depth-bounded[:N]` — like `queue-window` but only the first N
//!   chunks of each request's chain (default 8): caps per-request SSD
//!   read amplification when chains are long and DRAM is tight.

use crate::cache::chunk::ChunkedSeq;
use crate::cache::engine::CacheEngine;
use crate::cache::prefix_tree::NodeId;

/// Chunk-chain depth `depth-bounded` uses when no `:N` suffix is given.
pub const DEFAULT_DEPTH: usize = 8;

/// Selects which chunks the prefetcher should pull SSD→DRAM, given the
/// waiting queue's look-ahead window. Object-safe; the serving engine
/// holds a `Box<dyn PrefetchStrategy>` created by [`registry::parse`].
pub trait PrefetchStrategy: std::fmt::Debug + Send {
    /// Canonical (registry) name.
    fn name(&self) -> &'static str;

    /// Pick prefetch targets from `window` (queued requests' chunk
    /// chains, soonest-served first). Returned nodes are SSD-resident
    /// and absent from DRAM/GPU at selection time; the mover re-checks
    /// residency and de-duplicates in-flight loads, so duplicates and
    /// stale entries are tolerated.
    fn select_targets(&self, window: &[&ChunkedSeq], cache: &CacheEngine) -> Vec<NodeId>;
}

/// No prefetching.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoPrefetch;

impl PrefetchStrategy for NoPrefetch {
    fn name(&self) -> &'static str {
        "none"
    }

    fn select_targets(&self, _window: &[&ChunkedSeq], _cache: &CacheEngine) -> Vec<NodeId> {
        Vec::new()
    }
}

/// The paper's queue-based strategy (Algorithm 1's
/// `SubmitSSDToCPULoad` over the whole window).
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueWindow;

impl PrefetchStrategy for QueueWindow {
    fn name(&self) -> &'static str {
        "queue-window"
    }

    fn select_targets(&self, window: &[&ChunkedSeq], cache: &CacheEngine) -> Vec<NodeId> {
        let mut out = Vec::new();
        for chain in window.iter().rev() {
            out.extend(cache.prefetch_targets(&chain.keys));
        }
        out
    }
}

/// `queue-window` restricted to each chain's first `depth` chunks.
#[derive(Clone, Copy, Debug)]
pub struct DepthBounded {
    pub depth: usize,
}

impl PrefetchStrategy for DepthBounded {
    fn name(&self) -> &'static str {
        "depth-bounded"
    }

    fn select_targets(&self, window: &[&ChunkedSeq], cache: &CacheEngine) -> Vec<NodeId> {
        let mut out = Vec::new();
        for chain in window.iter().rev() {
            let take = chain.keys.len().min(self.depth);
            out.extend(cache.prefetch_targets(&chain.keys[..take]));
        }
        out
    }
}

/// Name-based strategy registry. `parse` is case-insensitive and
/// accepts a `depth-bounded:<n>` parameterisation.
pub mod registry {
    use super::*;

    /// Canonical names of every registered strategy.
    pub const NAMES: [&str; 3] = ["none", "queue-window", "depth-bounded"];

    /// Create a strategy by name (case-insensitive; `queue` is an
    /// alias for `queue-window`; `depth-bounded:<n>` overrides the
    /// default depth). Returns None for unregistered names or a
    /// malformed/zero depth.
    pub fn parse(name: &str) -> Option<Box<dyn PrefetchStrategy>> {
        let lower = name.to_ascii_lowercase();
        let strategy: Box<dyn PrefetchStrategy> = match lower.as_str() {
            "none" => Box::new(NoPrefetch),
            "queue-window" | "queue" => Box::new(QueueWindow),
            "depth-bounded" => Box::new(DepthBounded { depth: DEFAULT_DEPTH }),
            s => match s.strip_prefix("depth-bounded:") {
                Some(d) => {
                    let depth: usize = d.parse().ok()?;
                    if depth == 0 {
                        return None;
                    }
                    Box::new(DepthBounded { depth })
                }
                None => return None,
            },
        };
        Some(strategy)
    }

    /// Comma-separated registered names (for error messages).
    pub fn names_joined() -> String {
        NAMES.join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::ChunkedSeq;
    use crate::cache::engine::{CacheConfig, CacheEngine};
    use crate::cache::tier::Tier;

    const CB: u64 = 100;

    fn engine() -> CacheEngine {
        CacheEngine::new(CacheConfig {
            chunk_tokens: 4,
            gpu_capacity: 100 * CB,
            dram_capacity: 100 * CB,
            ssd_capacity: 100 * CB,
            policy: "lookahead-lru".into(),
        })
    }

    fn chain(tag: u32, chunks: usize) -> ChunkedSeq {
        let tokens: Vec<u32> = (0..(chunks * 4) as u32)
            .map(|i| i.wrapping_mul(31).wrapping_add(tag * 1_000_003))
            .collect();
        ChunkedSeq::new(&tokens, 4)
    }

    fn insert_ssd(cache: &mut CacheEngine, c: &ChunkedSeq) {
        let mut parent = None;
        for key in &c.keys {
            parent = cache.insert(parent, *key, CB, Tier::Ssd);
            assert!(parent.is_some());
        }
    }

    #[test]
    fn none_selects_nothing() {
        let mut cache = engine();
        let a = chain(1, 3);
        insert_ssd(&mut cache, &a);
        let s = registry::parse("none").unwrap();
        assert!(s.select_targets(&[&a], &cache).is_empty());
    }

    #[test]
    fn queue_window_covers_all_ssd_chunks_farthest_first() {
        let mut cache = engine();
        let a = chain(1, 2);
        let b = chain(2, 3);
        insert_ssd(&mut cache, &a);
        insert_ssd(&mut cache, &b);
        let s = registry::parse("queue-window").unwrap();
        let targets = s.select_targets(&[&a, &b], &cache);
        assert_eq!(targets.len(), 5);
        // farthest request (b) first
        let b0 = cache.tree.get(b.keys[0]).unwrap();
        assert_eq!(targets[0], b0);
    }

    #[test]
    fn queue_window_skips_dram_resident() {
        let mut cache = engine();
        let a = chain(3, 3);
        insert_ssd(&mut cache, &a);
        let id0 = cache.tree.get(a.keys[0]).unwrap();
        cache.promote(id0, Tier::Dram);
        let s = registry::parse("queue-window").unwrap();
        assert_eq!(s.select_targets(&[&a], &cache).len(), 2);
    }

    #[test]
    fn depth_bounded_truncates_each_chain() {
        let mut cache = engine();
        let a = chain(4, 6);
        insert_ssd(&mut cache, &a);
        let s = registry::parse("depth-bounded:2").unwrap();
        let targets = s.select_targets(&[&a], &cache);
        assert_eq!(targets.len(), 2);
        assert_eq!(targets[0], cache.tree.get(a.keys[0]).unwrap());
    }

    #[test]
    fn registry_parse_and_aliases() {
        for name in registry::NAMES {
            assert_eq!(registry::parse(name).unwrap().name(), name);
        }
        assert_eq!(registry::parse("QUEUE-WINDOW").unwrap().name(), "queue-window");
        assert_eq!(registry::parse("queue").unwrap().name(), "queue-window");
        assert_eq!(registry::parse("depth-bounded:4").unwrap().name(), "depth-bounded");
        assert!(registry::parse("depth-bounded:0").is_none());
        assert!(registry::parse("depth-bounded:x").is_none());
        assert!(registry::parse("bogus").is_none());
    }
}
