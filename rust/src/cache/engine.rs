//! The Cache Engine (paper Fig 6): multi-tier KV-chunk cache built on
//! the prefix tree, with policy-driven eviction, look-ahead protection,
//! and prefetch target selection. This is pure metadata/accounting —
//! byte movement is the serving layer's job (simulated via
//! `hw::transfer` channels, real via `cache::store` + `runtime`).

use crate::cache::chunk::ChunkKey;
use crate::cache::policy::{registry, EvictionPolicy};
use crate::cache::prefix_tree::{NodeId, PrefixTree};
use crate::cache::tier::{Tier, TierUsage};
use crate::cache::victim_index::VictimIndex;
use crate::obs::trace::{Kind, Phase, TraceEvent, Track};

/// Capacity/policy configuration of one cache engine instance. A tier
/// with zero capacity is disabled (e.g. the vLLM baseline has DRAM=0,
/// SSD=0; CCache has SSD=0).
#[derive(Clone, Debug)]
pub struct CacheConfig {
    pub chunk_tokens: usize,
    pub gpu_capacity: u64,
    pub dram_capacity: u64,
    pub ssd_capacity: u64,
    /// Eviction policy name, resolved through
    /// [`cache::policy::registry`](crate::cache::policy::registry)
    /// when the engine is built ([`CacheEngine::new`] panics on an
    /// unregistered name; validate upstream via the registry, or hand
    /// the engine a custom instance with [`CacheEngine::with_policy`]).
    pub policy: String,
}

impl CacheConfig {
    pub fn capacity(&self, tier: Tier) -> u64 {
        match tier {
            Tier::Gpu => self.gpu_capacity,
            Tier::Dram => self.dram_capacity,
            Tier::Ssd => self.ssd_capacity,
        }
    }

    pub fn tier_enabled(&self, tier: Tier) -> bool {
        self.capacity(tier) > 0
    }
}

/// Hit/miss/eviction counters (chunks and bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub lookups: u64,
    /// Chunks served per tier (fastest residency at lookup time).
    pub hit_chunks: [u64; 3],
    pub hit_bytes: [u64; 3],
    pub missed_chunks: u64,
    pub evicted_chunks: [u64; 3],
    pub inserted_chunks: [u64; 3],
    /// Inserts refused because eviction could not make room.
    pub rejected_inserts: u64,
}

impl CacheStats {
    pub fn total_hits(&self) -> u64 {
        self.hit_chunks.iter().sum()
    }

    pub fn hit_ratio(&self) -> f64 {
        let h = self.total_hits();
        let t = h + self.missed_chunks;
        if t == 0 {
            0.0
        } else {
            h as f64 / t as f64
        }
    }
}

/// A residency transition observed by one cache engine — the feed a
/// cluster's global prefix directory consumes to mirror replica-local
/// trees without walking them (see `cluster::directory`). Only *full*
/// transitions are reported: gaining a copy in a second tier, or
/// dropping one copy of a multi-tier chunk, changes nothing about
/// whether a replica can serve the chunk, so no event fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheEvent {
    /// The chunk gained its first resident copy (any tier).
    Resident(ChunkKey),
    /// The chunk lost its last resident copy.
    Gone(ChunkKey),
}

/// Result of matching one request's chunk chain against the cache.
#[derive(Clone, Debug, Default)]
pub struct Lookup {
    /// Matched prefix nodes, in chain order.
    pub nodes: Vec<NodeId>,
    /// Fastest tier each matched node is resident in.
    pub tiers: Vec<Tier>,
    /// Chunks counted per source tier.
    pub from: [u64; 3],
}

impl Lookup {
    pub fn matched_chunks(&self) -> usize {
        self.nodes.len()
    }
}

/// Multi-tier KV-cache engine.
#[derive(Debug)]
pub struct CacheEngine {
    pub tree: PrefixTree,
    pub usage: [TierUsage; 3],
    pub config: CacheConfig,
    pub stats: CacheStats,
    /// The eviction policy instance driving victim selection; its
    /// lifecycle hooks fire from [`lookup`](CacheEngine::lookup),
    /// [`insert`](CacheEngine::insert) and
    /// [`evict_one`](CacheEngine::evict_one).
    pub policy: Box<dyn EvictionPolicy>,
    /// Per-tier lazy rank heaps for amortized O(log n) victim selection
    /// (§Perf iteration 3, EXPERIMENTS.md). Consistency bookkeeping
    /// lives in the tree, so direct tree mutations (scheduler pins,
    /// prefetcher promotes) keep the index honest automatically.
    pub victim_index: VictimIndex,
    /// Pick victims through the incremental index (the default). Turn
    /// off to fall back to the fused O(n) reference scan — the parity
    /// oracle, and the baseline the eviction-pressure bench measures
    /// against.
    pub use_indexed_eviction: bool,
    /// Record residency transitions ([`CacheEvent`]) into
    /// [`events`](CacheEngine::events). Off by default (zero cost on
    /// the single-engine path); `cluster::Replica` turns it on and
    /// drains the buffer into the global prefix directory after every
    /// engine step.
    pub track_events: bool,
    /// Pending residency transitions, in occurrence order. Drain with
    /// [`take_events`](CacheEngine::take_events) — with `track_events`
    /// on and no consumer, this grows without bound.
    pub events: Vec<CacheEvent>,
    /// Observability feed, independent of [`track_events`]: when `Some`
    /// (the serving engine sets it iff tracing is on), every cache
    /// transition pushes a [`TraceEvent`] with a placeholder timestamp;
    /// the owner stamps the virtual clock and forwards to its tracer
    /// after each step. `None` (the default) costs one branch per hook.
    ///
    /// [`track_events`]: CacheEngine::track_events
    pub obs: Option<Vec<TraceEvent>>,
    sweep_countdown: u32,
}

impl CacheEngine {
    /// Build an engine with the policy named in `config` (resolved via
    /// the registry). Panics on an unregistered name — callers validate
    /// names upstream (`Config::validate`, CLI parsing).
    pub fn new(config: CacheConfig) -> Self {
        let policy = registry::parse(&config.policy).unwrap_or_else(|| {
            panic!(
                "unknown eviction policy '{}' (registered: {})",
                config.policy,
                registry::names_joined()
            )
        });
        Self::with_policy(config, policy)
    }

    /// Build an engine around a caller-supplied policy instance — the
    /// escape hatch for policies not in the registry (see the `cache`
    /// module docs for a worked example).
    pub fn with_policy(config: CacheConfig, policy: Box<dyn EvictionPolicy>) -> Self {
        CacheEngine {
            tree: PrefixTree::new(),
            usage: [
                TierUsage::new(config.gpu_capacity),
                TierUsage::new(config.dram_capacity),
                TierUsage::new(config.ssd_capacity),
            ],
            config,
            stats: CacheStats::default(),
            policy,
            victim_index: VictimIndex::new(),
            use_indexed_eviction: true,
            track_events: false,
            events: Vec::new(),
            obs: None,
            sweep_countdown: SWEEP_PERIOD,
        }
    }

    /// Push one cache transition onto the observability feed (no-op
    /// when tracing is off). Timestamps are placeholders — the owning
    /// engine stamps its virtual clock when it drains the buffer.
    #[inline]
    fn obs_push(&mut self, kind: Kind, id: u64) {
        if let Some(buf) = self.obs.as_mut() {
            buf.push(TraceEvent {
                t: 0.0,
                track: Track::Cache,
                kind,
                id,
                phase: Phase::Instant,
            });
        }
    }

    /// Match `chain` against the tree, touching hits (recency+freq) and
    /// recording per-tier hit stats. `total_chunks` is the request's
    /// full chain length (for miss accounting).
    pub fn lookup(&mut self, chain: &[ChunkKey]) -> Lookup {
        self.stats.lookups += 1;
        let nodes = self.tree.match_chain(chain);
        let mut out = Lookup::default();
        for id in nodes {
            let tier = self
                .tree
                .node(id)
                .tiers
                .fastest()
                .expect("matched node must be resident");
            self.tree.touch(id);
            self.policy.on_hit(&mut self.tree, id);
            out.from[tier.idx()] += 1;
            self.stats.hit_chunks[tier.idx()] += 1;
            self.stats.hit_bytes[tier.idx()] += self.tree.node(id).bytes;
            if self.obs.is_some() {
                let key = self.tree.node(id).key.0;
                self.obs_push(Kind::CacheHit, key);
            }
            out.tiers.push(tier);
            out.nodes.push(id);
        }
        self.stats.missed_chunks += (chain.len() - out.nodes.len()) as u64;
        out
    }

    /// Evict until `bytes` fit in `tier`. Returns false if impossible
    /// (all candidates pinned/locked or capacity simply too small).
    pub fn reserve(&mut self, tier: Tier, bytes: u64) -> bool {
        if bytes > self.usage[tier.idx()].capacity {
            return false;
        }
        while !self.usage[tier.idx()].fits(bytes) {
            if self.evict_one(tier).is_none() {
                return false;
            }
        }
        true
    }

    /// Evict one chunk from `tier` per the configured policy. Returns
    /// the evicted node. Victim selection goes through the incremental
    /// index (§Perf iteration 3) when enabled and the policy permits,
    /// else the fused allocation-free scan (§Perf iteration 1).
    pub fn evict_one(&mut self, tier: Tier) -> Option<NodeId> {
        let victim = if self.use_indexed_eviction && self.policy.indexable() {
            let CacheEngine { policy, tree, victim_index, .. } = self;
            policy.pick_victim_indexed(tree, tier, victim_index)?
        } else {
            self.policy.pick_victim_fused(&self.tree, tier)?
        };
        let bytes = self.tree.node(victim).bytes;
        // capture the key before dropping residency: maybe_sweep may
        // erase the now-absent node from the slab
        let key = self.tree.node(victim).key;
        let fully_gone = self.tree.remove_residency(victim, tier);
        self.usage[tier.idx()].sub(bytes);
        self.stats.evicted_chunks[tier.idx()] += 1;
        self.policy.on_evict(&mut self.tree, victim);
        if fully_gone {
            if self.track_events {
                self.events.push(CacheEvent::Gone(key));
            }
            self.obs_push(Kind::CacheEvict, key.0);
            self.maybe_sweep();
        }
        Some(victim)
    }

    /// Insert-or-promote `key` (child of `parent`) into `tier`,
    /// evicting as needed. Returns the node id, or None if room could
    /// not be made.
    pub fn insert(
        &mut self,
        parent: Option<NodeId>,
        key: ChunkKey,
        bytes: u64,
        tier: Tier,
    ) -> Option<NodeId> {
        if !self.config.tier_enabled(tier) {
            return None;
        }
        if let Some(id) = self.tree.get(key) {
            if self.tree.node(id).tiers.contains(tier) {
                return Some(id); // already resident here
            }
        }
        // The parent may itself be an evictable leaf right now — pin it
        // so making room for the child cannot evict its own prefix.
        if let Some(p) = parent {
            self.tree.pin(p);
        }
        let ok = self.reserve(tier, bytes);
        if let Some(p) = parent {
            self.tree.unpin(p);
        }
        if !ok {
            self.stats.rejected_inserts += 1;
            return None;
        }
        // new-chunk detection AFTER reserve: eviction pressure may have
        // fully evicted an existing node, making this a re-insertion
        let was_present = self
            .tree
            .get(key)
            .map(|id| !self.tree.node(id).tiers.is_empty())
            .unwrap_or(false);
        let id = self.tree.ensure(parent, key, bytes);
        self.tree.add_residency(id, tier);
        self.usage[tier.idx()].add(bytes);
        self.stats.inserted_chunks[tier.idx()] += 1;
        if !was_present {
            self.policy.on_insert(&mut self.tree, id);
            if self.track_events {
                self.events.push(CacheEvent::Resident(key));
            }
            self.obs_push(Kind::CacheInsert, key.0);
        }
        Some(id)
    }

    /// Promote an existing node into a (faster) tier — e.g. the
    /// prefetcher copying SSD→DRAM. No-op if already there.
    pub fn promote(&mut self, id: NodeId, tier: Tier) -> bool {
        if self.tree.node(id).tiers.contains(tier) {
            return true;
        }
        if !self.config.tier_enabled(tier) {
            return false;
        }
        // chain presence across tiers is inherited: the parent is
        // present somewhere (invariant), which is all reuse requires.
        let bytes = self.tree.node(id).bytes;
        if !self.reserve(tier, bytes) {
            return false;
        }
        // reserve's evictions cannot touch `id` (it has no copy in
        // `tier` yet), but a caller could promote a fully-absent node
        // back to residency — that is a directory-visible transition
        let was_absent = self.tree.node(id).tiers.is_empty();
        self.tree.add_residency(id, tier);
        self.usage[tier.idx()].add(bytes);
        self.stats.inserted_chunks[tier.idx()] += 1;
        if was_absent && self.track_events {
            self.events.push(CacheEvent::Resident(self.tree.node(id).key));
        }
        if self.obs.is_some() {
            let key = self.tree.node(id).key.0;
            self.obs_push(Kind::CachePromote, key);
        }
        true
    }

    /// Drop one node's copy in `tier` (explicit demotion, not policy
    /// eviction). Respects the leaf-only rule via debug assertions.
    pub fn demote(&mut self, id: NodeId, tier: Tier) {
        if !self.tree.node(id).tiers.contains(tier) {
            return;
        }
        let bytes = self.tree.node(id).bytes;
        let key = self.tree.node(id).key;
        let fully_gone = self.tree.remove_residency(id, tier);
        self.usage[tier.idx()].sub(bytes);
        if fully_gone && self.track_events {
            self.events.push(CacheEvent::Gone(key));
        }
        self.obs_push(Kind::CacheDemote, key.0);
    }

    /// Drain pending residency transitions (the cluster directory's
    /// event feed). Empty unless `track_events` is on.
    pub fn take_events(&mut self) -> Vec<CacheEvent> {
        std::mem::take(&mut self.events)
    }

    /// Look-ahead update (paper §4.2): walk a queued request's chain and
    /// protect matched chunks from eviction until `horizon` ticks from
    /// now. Also used by Algorithm 1's `BumpPriority`.
    pub fn boost_chain(&mut self, chain: &[ChunkKey], horizon: u64) -> usize {
        let nodes = self.tree.match_chain(chain);
        let until = self.tree.now() + horizon;
        let n = nodes.len();
        for id in nodes {
            self.tree.boost(id, until);
        }
        n
    }

    /// Chunks of `chain` that are on SSD but not yet in DRAM/GPU — the
    /// prefetcher's SSD→DRAM work list (Algorithm 1's
    /// `SubmitSSDToCPULoad`).
    pub fn prefetch_targets(&self, chain: &[ChunkKey]) -> Vec<NodeId> {
        self.tree
            .match_chain(chain)
            .into_iter()
            .filter(|id| {
                let t = self.tree.node(*id).tiers;
                t.contains(Tier::Ssd) && !t.contains(Tier::Dram) && !t.contains(Tier::Gpu)
            })
            .collect()
    }

    pub fn used(&self, tier: Tier) -> u64 {
        self.usage[tier.idx()].used
    }

    /// Cross-check running byte counters against the tree (tests).
    pub fn check_accounting(&self) -> Result<(), String> {
        self.tree.check_invariants()?;
        for t in Tier::ALL {
            let actual = self.tree.resident_bytes(t);
            if actual != self.usage[t.idx()].used {
                return Err(format!(
                    "{} usage mismatch: counter {} tree {}",
                    t.name(),
                    self.usage[t.idx()].used,
                    actual
                ));
            }
        }
        Ok(())
    }

    /// Drop the victim index and queue a lazy rebuild over every live
    /// node. Needed only after rank inputs changed *outside* the
    /// tree's event bookkeeping — e.g. a custom policy re-ranking
    /// through hidden global state (see the `cache` module docs). O(n)
    /// queueing now; re-ranking happens incrementally at pick time.
    pub fn force_reindex(&mut self) {
        self.victim_index.clear();
        self.tree.requeue_all();
    }

    fn maybe_sweep(&mut self) {
        self.sweep_countdown -= 1;
        if self.sweep_countdown == 0 {
            self.tree.sweep_absent();
            self.sweep_countdown = SWEEP_PERIOD;
        }
    }

    /// Quarantine a chunk whose stored bytes turned out to be
    /// unreadable (lost, corrupted, or retries exhausted): drop every
    /// resident copy of `id` *and of its resident subtree*, so the
    /// request re-plans onto the recompute path and the directory
    /// learns the chunks are gone.
    ///
    /// The subtree goes too because (a) the leaf-only removal rule
    /// forbids dropping a mid-chain node's last copy while descendants
    /// are resident, and (b) descendants of an absent node are
    /// unreachable for prefix reuse anyway (`match_chain` stops at the
    /// first absent link) — keeping them would be dead weight that
    /// only eviction pressure could reclaim. Pins are deliberately
    /// ignored: callers unpin their movement plan first, and a chunk
    /// that cannot be read must not stay resident no matter who
    /// planned to use it.
    ///
    /// Returns the number of chunks dropped (≥ 1: `id` itself plus
    /// resident-subtree collateral).
    pub fn quarantine(&mut self, id: NodeId) -> u64 {
        // Collect the subtree, then drop residency children-first so
        // every removal observes the leaf-only rule.
        let mut order = vec![id];
        let mut i = 0;
        while i < order.len() {
            order.extend(self.tree.children_of(order[i]).iter().copied());
            i += 1;
        }
        let mut dropped = 0u64;
        let mut fully_gone = 0u32;
        for &n in order.iter().rev() {
            if self.tree.node(n).tiers.is_empty() {
                continue;
            }
            let bytes = self.tree.node(n).bytes;
            let key = self.tree.node(n).key;
            for tier in [Tier::Gpu, Tier::Dram, Tier::Ssd] {
                if !self.tree.node(n).tiers.contains(tier) {
                    continue;
                }
                self.tree.remove_residency(n, tier);
                self.usage[tier.idx()].sub(bytes);
                self.stats.evicted_chunks[tier.idx()] += 1;
            }
            self.policy.on_evict(&mut self.tree, n);
            dropped += 1;
            fully_gone += 1;
            if self.track_events {
                self.events.push(CacheEvent::Gone(key));
            }
            self.obs_push(Kind::CacheQuarantine, key.0);
        }
        // Sweep bookkeeping after all removals so an eager sweep can
        // never erase a node the loop still has to visit.
        for _ in 0..fully_gone {
            self.maybe_sweep();
        }
        dropped
    }
}

const SWEEP_PERIOD: u32 = 256;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};
    use crate::util::proptest::{check, forall};
    use crate::util::rng::Rng;

    const CHUNK_BYTES: u64 = 100;

    fn cfg(gpu: u64, dram: u64, ssd: u64) -> CacheConfig {
        CacheConfig {
            chunk_tokens: 4,
            gpu_capacity: gpu,
            dram_capacity: dram,
            ssd_capacity: ssd,
            policy: "lookahead-lru".into(),
        }
    }

    fn chain_of(tag: u32, n: usize) -> Vec<ChunkKey> {
        let mut keys = Vec::new();
        let mut parent = ChunkKey::ROOT;
        for i in 0..n {
            let k = chain_hash(parent, &[tag, i as u32]);
            keys.push(k);
            parent = k;
        }
        keys
    }

    fn insert_chain(e: &mut CacheEngine, chain: &[ChunkKey], tier: Tier) -> Vec<NodeId> {
        let mut parent = None;
        let mut out = Vec::new();
        for k in chain {
            match e.insert(parent, *k, CHUNK_BYTES, tier) {
                Some(id) => {
                    out.push(id);
                    parent = Some(id);
                }
                None => break,
            }
        }
        out
    }

    #[test]
    fn lookup_hit_and_miss_accounting() {
        let mut e = CacheEngine::new(cfg(0, 1000, 0));
        let c = chain_of(1, 3);
        insert_chain(&mut e, &c, Tier::Dram);
        let l = e.lookup(&c);
        assert_eq!(l.matched_chunks(), 3);
        assert_eq!(l.from[Tier::Dram.idx()], 3);
        let c2 = chain_of(2, 2);
        let l2 = e.lookup(&c2);
        assert_eq!(l2.matched_chunks(), 0);
        assert_eq!(e.stats.missed_chunks, 2);
        assert!((e.stats.hit_ratio() - 0.6).abs() < 1e-12);
        e.check_accounting().unwrap();
    }

    #[test]
    fn eviction_makes_room_leaf_first() {
        // capacity for 3 chunks; inserting a 4th evicts the LRU leaf
        let mut e = CacheEngine::new(cfg(0, 300, 0));
        let a = chain_of(1, 2); // chain a1 -> a2
        let b = chain_of(2, 1); // independent b1
        insert_chain(&mut e, &a, Tier::Dram);
        insert_chain(&mut e, &b, Tier::Dram);
        assert_eq!(e.used(Tier::Dram), 300);
        let c = chain_of(3, 1);
        let got = insert_chain(&mut e, &c, Tier::Dram);
        assert_eq!(got.len(), 1);
        assert_eq!(e.used(Tier::Dram), 300);
        assert_eq!(e.stats.evicted_chunks[Tier::Dram.idx()], 1);
        // a1 (a non-leaf) must still be present
        assert!(e.tree.get(a[0]).map(|id| !e.tree.node(id).tiers.is_empty()).unwrap_or(false));
        e.check_accounting().unwrap();
    }

    #[test]
    fn lookahead_protects_queued_chunks() {
        let mut e = CacheEngine::new(cfg(0, 200, 0));
        let a = chain_of(1, 1);
        let b = chain_of(2, 1);
        insert_chain(&mut e, &a, Tier::Dram); // oldest
        insert_chain(&mut e, &b, Tier::Dram);
        // a queued request references chain a: protect it
        e.boost_chain(&a, 1000);
        let c = chain_of(3, 1);
        insert_chain(&mut e, &c, Tier::Dram);
        // b (second-oldest) was evicted instead of a
        let a_alive = !e.tree.node(e.tree.get(a[0]).unwrap()).tiers.is_empty();
        assert!(a_alive);
        assert!(e.tree.get(b[0]).map(|id| e.tree.node(id).tiers.is_empty()).unwrap_or(true));
    }

    #[test]
    fn quarantine_drops_node_and_resident_subtree() {
        let mut e = CacheEngine::new(cfg(300, 1000, 1000));
        e.track_events = true;
        let c = chain_of(1, 4);
        let ids = insert_chain(&mut e, &c, Tier::Ssd);
        // the deepest node also holds a GPU copy: quarantining an
        // ancestor must reclaim descendants' copies in *every* tier
        assert!(e.promote(ids[3], Tier::Gpu));
        e.take_events();
        // quarantine the 2nd chunk: it and its resident subtree (3rd,
        // 4th) go; the 1st survives
        let dropped = e.quarantine(ids[1]);
        assert_eq!(dropped, 3);
        assert!(!e.tree.node(ids[0]).tiers.is_empty());
        for id in &ids[1..] {
            assert!(e.tree.node(*id).tiers.is_empty());
        }
        assert_eq!(e.used(Tier::Ssd), CHUNK_BYTES);
        assert_eq!(e.used(Tier::Gpu), 0);
        assert_eq!(e.stats.evicted_chunks[Tier::Ssd.idx()], 3);
        assert_eq!(e.stats.evicted_chunks[Tier::Gpu.idx()], 1);
        // the directory feed sees every fully-gone chunk
        let gone: Vec<_> = e
            .take_events()
            .into_iter()
            .filter(|ev| matches!(ev, CacheEvent::Gone(_)))
            .collect();
        assert_eq!(gone.len(), 3);
        e.check_accounting().unwrap();
        e.tree.check_invariants().unwrap();
        // a re-match stops before the quarantined link
        let l = e.lookup(&c);
        assert_eq!(l.matched_chunks(), 1);
    }

    #[test]
    fn quarantine_of_leaf_touches_nothing_else() {
        let mut e = CacheEngine::new(cfg(0, 0, 1000));
        let c = chain_of(2, 3);
        let ids = insert_chain(&mut e, &c, Tier::Ssd);
        assert_eq!(e.quarantine(ids[2]), 1);
        assert_eq!(e.used(Tier::Ssd), 2 * CHUNK_BYTES);
        assert!(!e.tree.node(ids[1]).tiers.is_empty());
        e.check_accounting().unwrap();
    }

    #[test]
    fn disabled_tier_rejects_insert() {
        let mut e = CacheEngine::new(cfg(0, 1000, 0));
        let c = chain_of(1, 1);
        assert!(e.insert(None, c[0], CHUNK_BYTES, Tier::Ssd).is_none());
    }

    #[test]
    fn oversized_insert_rejected() {
        let mut e = CacheEngine::new(cfg(0, 150, 0));
        let c = chain_of(1, 2);
        let got = insert_chain(&mut e, &c, Tier::Dram);
        assert_eq!(got.len(), 1); // second chunk cannot fit (parent locked)
        assert_eq!(e.stats.rejected_inserts, 1);
        e.check_accounting().unwrap();
    }

    #[test]
    fn promote_ssd_to_dram() {
        let mut e = CacheEngine::new(cfg(0, 100, 1000));
        let c = chain_of(1, 3);
        let ids = insert_chain(&mut e, &c, Tier::Ssd);
        assert_eq!(ids.len(), 3);
        assert!(e.promote(ids[0], Tier::Dram));
        assert_eq!(e.used(Tier::Dram), 100);
        // DRAM full: promoting another evicts the first from DRAM (it
        // still has its SSD copy, so dropping the DRAM copy is legal
        // even though it has children).
        assert!(e.promote(ids[1], Tier::Dram));
        assert_eq!(e.used(Tier::Dram), 100);
        let t0 = e.tree.node(ids[0]).tiers;
        assert!(t0.contains(Tier::Ssd));
        e.check_accounting().unwrap();
    }

    #[test]
    fn prefetch_targets_are_ssd_only_chunks() {
        let mut e = CacheEngine::new(cfg(0, 100, 1000));
        let c = chain_of(1, 3);
        let ids = insert_chain(&mut e, &c, Tier::Ssd);
        e.promote(ids[0], Tier::Dram);
        let targets = e.prefetch_targets(&c);
        assert_eq!(targets, vec![ids[1], ids[2]]);
    }

    #[test]
    fn pinned_chunks_survive_pressure() {
        let mut e = CacheEngine::new(cfg(0, 200, 0));
        let a = chain_of(1, 1);
        let b = chain_of(2, 1);
        let ia = insert_chain(&mut e, &a, Tier::Dram)[0];
        insert_chain(&mut e, &b, Tier::Dram);
        e.tree.pin(ia);
        let c = chain_of(3, 1);
        insert_chain(&mut e, &c, Tier::Dram);
        assert!(!e.tree.node(ia).tiers.is_empty(), "pinned chunk evicted");
        e.tree.unpin(ia);
        e.check_accounting().unwrap();
    }

    #[test]
    fn reserve_fails_when_everything_pinned() {
        let mut e = CacheEngine::new(cfg(0, 100, 0));
        let a = chain_of(1, 1);
        let ia = insert_chain(&mut e, &a, Tier::Dram)[0];
        e.tree.pin(ia);
        assert!(!e.reserve(Tier::Dram, 100));
        e.tree.unpin(ia);
        assert!(e.reserve(Tier::Dram, 100));
    }

    #[test]
    fn demote_then_reinsert() {
        let mut e = CacheEngine::new(cfg(0, 1000, 0));
        let c = chain_of(1, 2);
        let ids = insert_chain(&mut e, &c, Tier::Dram);
        e.demote(ids[1], Tier::Dram);
        assert_eq!(e.used(Tier::Dram), 100);
        let l = e.lookup(&c);
        assert_eq!(l.matched_chunks(), 1);
        // reinsert the dropped chunk
        let id2 = e.insert(Some(ids[0]), c[1], CHUNK_BYTES, Tier::Dram);
        assert!(id2.is_some());
        e.check_accounting().unwrap();
    }

    #[test]
    fn indexed_and_fused_paths_evict_identically() {
        for name in crate::cache::policy::registry::NAMES {
            let mk = || {
                CacheEngine::new(CacheConfig {
                    chunk_tokens: 4,
                    gpu_capacity: 0,
                    dram_capacity: u64::MAX / 4,
                    ssd_capacity: u64::MAX / 4,
                    policy: name.to_string(),
                })
            };
            let mut a = mk(); // indexed (the default)
            let mut b = mk();
            b.use_indexed_eviction = false;
            assert!(a.use_indexed_eviction && a.policy.indexable());
            // identical op sequences on both engines
            for e in [&mut a, &mut b] {
                for tag in 0..8u32 {
                    insert_chain(e, &chain_of(tag, 1 + tag as usize % 3), Tier::Dram);
                }
                e.lookup(&chain_of(2, 3));
                e.lookup(&chain_of(5, 3));
                e.boost_chain(&chain_of(0, 1), 500);
                for id in e.prefetch_targets(&chain_of(3, 1)) {
                    e.promote(id, Tier::Dram);
                }
            }
            // drain both to empty: every victim must match, in order
            loop {
                let va = a.evict_one(Tier::Dram);
                let vb = b.evict_one(Tier::Dram);
                assert_eq!(va, vb, "eviction order diverged for {name}");
                if va.is_none() {
                    break;
                }
            }
            a.check_accounting().unwrap();
        }
    }

    #[test]
    fn force_reindex_recovers_from_out_of_band_rank_change() {
        let mut e = CacheEngine::new(cfg(0, 1000, 0));
        let a = chain_of(1, 1);
        let b = chain_of(2, 1);
        let ia = insert_chain(&mut e, &a, Tier::Dram)[0];
        insert_chain(&mut e, &b, Tier::Dram);
        // warm the index, then clear it to simulate drift
        let CacheEngine { policy, tree, victim_index, .. } = &mut e;
        let warm = policy.pick_victim_indexed(tree, Tier::Dram, victim_index);
        assert_eq!(warm, Some(ia));
        e.force_reindex();
        // index rebuilt lazily from requeue_all: same answer, and
        // eviction proceeds normally
        assert_eq!(e.evict_one(Tier::Dram), Some(ia));
        e.check_accounting().unwrap();
    }

    #[test]
    fn residency_events_track_full_transitions_only() {
        let mut e = CacheEngine::new(cfg(0, 200, 1000));
        e.track_events = true;
        let c = chain_of(1, 1);
        insert_chain(&mut e, &c, Tier::Dram);
        assert_eq!(e.take_events(), vec![CacheEvent::Resident(c[0])]);
        // a second-tier copy of the same chunk is not a transition
        insert_chain(&mut e, &c, Tier::Ssd);
        assert!(e.take_events().is_empty());
        // dropping the DRAM copy leaves the SSD copy: still resident
        let id = e.tree.get(c[0]).unwrap();
        e.demote(id, Tier::Dram);
        assert!(e.take_events().is_empty());
        // dropping the last copy is a full transition
        e.demote(id, Tier::Ssd);
        assert_eq!(e.take_events(), vec![CacheEvent::Gone(c[0])]);
        // re-insertion after full absence is a fresh Resident
        insert_chain(&mut e, &c, Tier::Dram);
        assert_eq!(e.take_events(), vec![CacheEvent::Resident(c[0])]);
        // promote back from SSD after the DRAM copy is demoted away:
        // demote emits Gone only when no copy remains anywhere
        insert_chain(&mut e, &c, Tier::Ssd);
        e.take_events();
        e.demote(e.tree.get(c[0]).unwrap(), Tier::Dram);
        assert!(e.take_events().is_empty());
        e.check_accounting().unwrap();
    }

    #[test]
    fn eviction_pressure_emits_gone_for_single_copy_chunks() {
        let mut e = CacheEngine::new(cfg(0, 200, 0));
        e.track_events = true;
        let a = chain_of(1, 1);
        let b = chain_of(2, 1);
        insert_chain(&mut e, &a, Tier::Dram);
        insert_chain(&mut e, &b, Tier::Dram);
        e.take_events();
        // full DRAM: inserting c evicts the LRU chunk a entirely
        let c = chain_of(3, 1);
        insert_chain(&mut e, &c, Tier::Dram);
        let evs = e.take_events();
        assert!(evs.contains(&CacheEvent::Gone(a[0])), "{evs:?}");
        assert!(evs.contains(&CacheEvent::Resident(c[0])), "{evs:?}");
    }

    #[test]
    fn events_are_off_by_default() {
        let mut e = CacheEngine::new(cfg(0, 200, 0));
        insert_chain(&mut e, &chain_of(1, 1), Tier::Dram);
        assert!(!e.track_events);
        assert!(e.take_events().is_empty());
    }

    #[test]
    fn obs_feed_covers_every_cache_transition() {
        // off by default: nothing allocated, nothing recorded
        let mut e = CacheEngine::new(cfg(0, 200, 1000));
        insert_chain(&mut e, &chain_of(9, 1), Tier::Dram);
        assert!(e.obs.is_none());
        // on: each transition pushes a placeholder-stamped instant
        e.obs = Some(Vec::new());
        let c = chain_of(1, 2);
        let ids = insert_chain(&mut e, &c, Tier::Ssd);
        e.lookup(&c);
        e.promote(ids[0], Tier::Dram);
        e.demote(ids[0], Tier::Dram);
        insert_chain(&mut e, &chain_of(2, 1), Tier::Dram); // evicts chunk 9
        insert_chain(&mut e, &chain_of(3, 1), Tier::Dram);
        e.quarantine(ids[0]);
        let kinds: std::collections::BTreeSet<&str> = e
            .obs
            .as_ref()
            .unwrap()
            .iter()
            .map(|ev| ev.kind.name())
            .collect();
        for want in [
            "cache_insert",
            "cache_hit",
            "cache_promote",
            "cache_demote",
            "cache_evict",
            "cache_quarantine",
        ] {
            assert!(kinds.contains(want), "missing {want} in {kinds:?}");
        }
        for ev in e.obs.as_ref().unwrap() {
            assert_eq!(ev.track, Track::Cache);
            assert_eq!(ev.phase, Phase::Instant);
        }
        e.check_accounting().unwrap();
    }

    /// Property: after an arbitrary interleaving of inserts, lookups,
    /// promotions and reserve-pressure, all structural invariants and
    /// byte accounting hold, and no tier exceeds capacity.
    #[test]
    fn prop_engine_invariants_under_random_ops() {
        forall(
            0xC0FFEE,
            60,
            |rng: &mut Rng| {
                let n = 3 + rng.below(40) as usize;
                (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
            },
            |ops| {
                let mut e = CacheEngine::new(CacheConfig {
                    chunk_tokens: 4,
                    gpu_capacity: 300,
                    dram_capacity: 500,
                    ssd_capacity: 800,
                    policy: "lookahead-lru".into(),
                });
                let chains: Vec<Vec<ChunkKey>> =
                    (0..6).map(|t| chain_of(t, 1 + (t as usize % 4))).collect();
                for op in ops {
                    let chain = &chains[(op % 6) as usize];
                    match (op >> 8) % 5 {
                        0 => {
                            insert_chain(&mut e, chain, Tier::Dram);
                        }
                        1 => {
                            insert_chain(&mut e, chain, Tier::Ssd);
                        }
                        2 => {
                            e.lookup(chain);
                        }
                        3 => {
                            e.boost_chain(chain, (op >> 16) % 64);
                        }
                        _ => {
                            for id in e.prefetch_targets(chain) {
                                e.promote(id, Tier::Dram);
                            }
                        }
                    }
                    if let Err(m) = e.check_accounting() {
                        return Err(m);
                    }
                    for t in Tier::ALL {
                        if e.usage[t.idx()].used > e.usage[t.idx()].capacity {
                            return Err(format!("{} over capacity", t.name()));
                        }
                    }
                }
                check(true, "")
            },
        );
    }
}
