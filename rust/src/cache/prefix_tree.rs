//! The prefix tree of KV chunks (paper §4.2, Fig 7).
//!
//! Each node is one chunk's KV cache, keyed by its prefix-chain hash;
//! children extend the parent's token prefix. Residency across the
//! GPU/DRAM/SSD tiers is tracked per node, with two structural
//! invariants the eviction machinery preserves (property-tested in
//! `cache::engine`):
//!
//!   1. **Chain presence** — a node resident in any tier has its parent
//!      resident in some tier (a chunk's KV is useless without its full
//!      prefix; paper: "each child node depends on its parent").
//!   2. **Leaf-only removal** — a node may lose its *last* tier copy
//!      only if no descendant is present (paper: "eviction is
//!      restricted to the leaf nodes").

use crate::cache::chunk::ChunkKey;
use crate::cache::tier::{Tier, TierSet};
use crate::util::fxhash::FxHashMap;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Slab index of a tree node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// One KV chunk's metadata.
#[derive(Clone, Debug)]
pub struct Node {
    pub key: ChunkKey,
    pub parent: Option<NodeId>,
    /// Bytes of this chunk's KV cache (all layers).
    pub bytes: u64,
    pub tiers: TierSet,
    /// Children with non-empty residency.
    pub present_children: u32,
    /// In-flight uses (pinned chunks are not evictable).
    pub pins: u32,
    /// Recency clock value of the last touch (LRU).
    pub last_access: u64,
    /// Clock value at insert (FIFO).
    pub inserted_at: u64,
    /// Touch count (PGDSF frequency term).
    pub freq: u64,
    /// Look-ahead protection: leaf is skipped by look-ahead LRU while
    /// `boost_until > now` (scheduler bumps this from the waiting queue).
    pub boost_until: u64,
    /// Policy-owned metadata slot. The tree never interprets it; the
    /// configured `EvictionPolicy` reads/writes it through its
    /// lifecycle hooks (e.g. SLRU's segment bit, LFUDA's cached
    /// priority). Reset to 0 on (re-)insertion via `on_insert`.
    pub policy_meta: u64,
}

/// The prefix tree + global key index.
#[derive(Debug, Default)]
pub struct PrefixTree {
    nodes: Vec<Node>,
    free: Vec<u32>,
    /// Liveness bitmap parallel to `nodes` (slab slots in `free` are
    /// dead). Lets hot scans iterate the slab contiguously instead of
    /// hashing through `index` (§Perf iteration 2).
    live: Vec<bool>,
    index: FxHashMap<ChunkKey, NodeId>,
    /// Children adjacency (node -> child ids). Parallel to `nodes`.
    children: Vec<Vec<NodeId>>,
    clock: u64,
    /// Per-slot rank generation (§Perf iteration 3). Every event that
    /// can change a node's victim rank or evictability — touch, boost,
    /// policy-meta writes, pin/unpin, residency and `present_children`
    /// changes, slot reuse — bumps the slot's generation. The victim
    /// index stamps heap entries with the generation at push time and
    /// discards mismatched (stale) entries lazily at pick time.
    gens: Vec<u64>,
    /// Per-slot 3-bit mask, parallel to `nodes`: bit `t` set ⟺ the
    /// slot has exactly one entry waiting in `pending[t]`. Keeps the
    /// pending queues duplicate-free without a hash set.
    queued: Vec<u8>,
    /// Per-tier queues of nodes whose rank inputs changed since they
    /// were last indexed for that tier. O(1) amortized per event;
    /// drained by the victim index before each indexed pick.
    pending: [Vec<NodeId>; 3],
    /// Boost horizons yet to expire, ordered soonest-first. Boost
    /// *expiry* is the one rank change driven by clock movement alone
    /// (no per-node mutation), so it gets an explicit queue: see
    /// [`PrefixTree::expire_boosts`].
    boost_expiry: BinaryHeap<Reverse<(u64, NodeId)>>,
}

impl PrefixTree {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    /// Direct children of `id` (any residency state).
    pub fn children_of(&self, id: NodeId) -> &[NodeId] {
        &self.children[id.0 as usize]
    }

    pub fn get(&self, key: ChunkKey) -> Option<NodeId> {
        self.index.get(&key).copied()
    }

    /// Advance and return the recency clock.
    pub fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    pub fn now(&self) -> u64 {
        self.clock
    }

    /// Longest prefix of `chain` whose nodes are all *present*
    /// (resident somewhere). Returns the matched node ids in order.
    pub fn match_chain(&self, chain: &[ChunkKey]) -> Vec<NodeId> {
        let mut out = Vec::with_capacity(chain.len());
        for key in chain {
            match self.index.get(key) {
                Some(&id) if !self.node(id).tiers.is_empty() => out.push(id),
                _ => break,
            }
        }
        out
    }

    /// Insert-or-get the node for `key` whose parent is the last element
    /// of the already-present chain (None = root-level chunk). The new
    /// node starts with empty residency; callers make it resident via
    /// [`PrefixTree::add_residency`].
    pub fn ensure(&mut self, parent: Option<NodeId>, key: ChunkKey, bytes: u64) -> NodeId {
        if let Some(&id) = self.index.get(&key) {
            return id;
        }
        if let Some(p) = parent {
            debug_assert!(
                !self.node(p).tiers.is_empty(),
                "parent must be present before inserting a child"
            );
        }
        let now = self.tick();
        let node = Node {
            key,
            parent,
            bytes,
            tiers: TierSet::EMPTY,
            present_children: 0,
            pins: 0,
            last_access: now,
            inserted_at: now,
            freq: 0,
            boost_until: 0,
            policy_meta: 0,
        };
        let id = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = node;
                self.children[slot as usize].clear();
                self.live[slot as usize] = true;
                NodeId(slot)
            }
            None => {
                self.nodes.push(node);
                self.children.push(Vec::new());
                self.live.push(true);
                self.gens.push(0);
                self.queued.push(0);
                NodeId(self.nodes.len() as u32 - 1)
            }
        };
        if let Some(p) = parent {
            self.children[p.0 as usize].push(id);
        }
        self.index.insert(key, id);
        // Rank inputs (inserted_at, bytes, ...) are fresh for this slot:
        // invalidate any heap entry left over from a previous occupant.
        self.mark(id);
        id
    }

    /// Make `id` resident in `tier`. Maintains the chain-presence
    /// invariant bookkeeping (parent's present_children).
    pub fn add_residency(&mut self, id: NodeId, tier: Tier) {
        let was_present = !self.node(id).tiers.is_empty();
        if self.node(id).tiers.contains(tier) {
            return;
        }
        if !was_present {
            if let Some(p) = self.node(id).parent {
                debug_assert!(
                    !self.node(p).tiers.is_empty(),
                    "chain-presence violated: parent absent"
                );
                self.node_mut(p).present_children += 1;
                // parent may have just stopped being evictable
                self.mark(p);
            }
        }
        self.node_mut(id).tiers.insert(tier);
        // gaining a copy can make the *other* tiers' copies evictable
        self.mark(id);
    }

    /// Drop `id`'s copy in `tier`. Returns true if the node is now
    /// absent everywhere (fully evicted). Enforces leaf-only removal:
    /// panics (debug) if the last copy of a node with present children
    /// is dropped.
    pub fn remove_residency(&mut self, id: NodeId, tier: Tier) -> bool {
        if !self.node(id).tiers.contains(tier) {
            return self.node(id).tiers.is_empty();
        }
        self.node_mut(id).tiers.remove(tier);
        // losing a copy can make the remaining (now last) copy
        // non-evictable; requeue whatever tiers are left
        self.mark(id);
        if self.node(id).tiers.is_empty() {
            debug_assert_eq!(
                self.node(id).present_children, 0,
                "leaf-only removal violated"
            );
            if let Some(p) = self.node(id).parent {
                self.node_mut(p).present_children -= 1;
                // parent may have just become an evictable leaf
                self.mark(p);
            }
            true
        } else {
            false
        }
    }

    /// Remove a fully-absent node from the tree entirely (frees the
    /// slab slot). Only valid for nodes with no children in the tree.
    pub fn erase(&mut self, id: NodeId) {
        assert!(self.node(id).tiers.is_empty(), "erase of resident node");
        assert!(
            self.children[id.0 as usize].is_empty(),
            "erase of node with children"
        );
        if let Some(p) = self.node(id).parent {
            self.children[p.0 as usize].retain(|c| *c != id);
        }
        let key = self.node(id).key;
        self.index.remove(&key);
        // invalidate any heap entries still pointing at this slot
        // before it can be reused for a different key
        self.gens[id.0 as usize] = self.gens[id.0 as usize].wrapping_add(1);
        self.free.push(id.0);
        self.live[id.0 as usize] = false;
    }

    /// Garbage-collect absent childless nodes. Erasing a leaf can make
    /// its (absent) parent childless, so sweep to a fixpoint.
    pub fn sweep_absent(&mut self) {
        loop {
            let ids: Vec<NodeId> = self
                .index
                .values()
                .copied()
                .filter(|id| {
                    self.node(*id).tiers.is_empty()
                        && self.children[id.0 as usize].is_empty()
                })
                .collect();
            if ids.is_empty() {
                break;
            }
            for id in ids {
                self.erase(id);
            }
        }
    }

    /// Touch for recency + frequency (on every reuse hit).
    pub fn touch(&mut self, id: NodeId) {
        let now = self.tick();
        let n = self.node_mut(id);
        n.last_access = now;
        n.freq += 1;
        self.mark(id);
    }

    /// Look-ahead protection: the look-ahead LRU policy will avoid
    /// evicting this node while `now < until`.
    pub fn boost(&mut self, id: NodeId, until: u64) {
        let now = self.clock;
        let n = self.node_mut(id);
        let grew = until > n.boost_until;
        n.boost_until = n.boost_until.max(until);
        if grew {
            if until > now {
                // schedule the flip back to unprotected — the only
                // rank change that happens by clock movement alone
                self.boost_expiry.push(Reverse((until, id)));
            }
            self.mark(id);
        }
    }

    /// Write the policy-owned metadata slot (see [`Node::policy_meta`]).
    pub fn set_policy_meta(&mut self, id: NodeId, meta: u64) {
        if self.node(id).policy_meta != meta {
            self.node_mut(id).policy_meta = meta;
            self.mark(id);
        }
    }

    pub fn pin(&mut self, id: NodeId) {
        self.node_mut(id).pins += 1;
        self.mark(id);
    }

    pub fn unpin(&mut self, id: NodeId) {
        let n = self.node_mut(id);
        assert!(n.pins > 0, "unpin without pin");
        n.pins -= 1;
        self.mark(id);
    }

    /// Record a rank-affecting event on `id`: bump its generation
    /// (invalidating stale victim-index entries) and queue it for
    /// re-indexing in every tier it is resident in. O(1) amortized —
    /// the `queued` bitmask guarantees at most one pending entry per
    /// (slot, tier).
    fn mark(&mut self, id: NodeId) {
        let i = id.0 as usize;
        self.gens[i] = self.gens[i].wrapping_add(1);
        let tiers = self.nodes[i].tiers;
        for t in Tier::ALL {
            let bit = 1u8 << t.idx();
            if tiers.contains(t) && self.queued[i] & bit == 0 {
                self.queued[i] |= bit;
                self.pending[t.idx()].push(id);
            }
        }
    }

    /// Current rank generation of `id`. A victim-index entry stamped
    /// with an older generation is stale: some rank input changed after
    /// it was pushed.
    pub fn rank_gen(&self, id: NodeId) -> u64 {
        self.gens[id.0 as usize]
    }

    /// Pop one node queued for (re-)indexing in `tier`, if any.
    pub fn take_pending(&mut self, tier: Tier) -> Option<NodeId> {
        let id = self.pending[tier.idx()].pop()?;
        self.queued[id.0 as usize] &= !(1u8 << tier.idx());
        Some(id)
    }

    /// Nodes currently queued for (re-)indexing in `tier`.
    pub fn pending_len(&self, tier: Tier) -> usize {
        self.pending[tier.idx()].len()
    }

    /// Requeue boosted nodes whose protection horizon has passed. Their
    /// look-ahead class flipped without any per-node event, so the
    /// victim index calls this before every pick to keep lazily-stored
    /// ranks from under-reporting staleness. Amortized O(log n) per
    /// boost over the whole run.
    pub fn expire_boosts(&mut self) {
        while let Some(&Reverse((until, id))) = self.boost_expiry.peek() {
            if until > self.clock {
                break;
            }
            self.boost_expiry.pop();
            // the slot may have been erased/reused since the boost was
            // scheduled; mark is still safe (gen bump + requeue of
            // whatever is resident there now, which is conservative)
            self.mark(id);
        }
    }

    /// Queue every live node for re-indexing in all its resident tiers
    /// — the big hammer behind `CacheEngine::force_reindex`, for
    /// policies whose ranks changed out of band.
    pub fn requeue_all(&mut self) {
        for i in 0..self.nodes.len() {
            if self.live[i] {
                self.mark(NodeId(i as u32));
            }
        }
    }

    /// Whether dropping `id` from `tier` is allowed right now:
    /// resident there, unpinned, and (copy elsewhere OR no present
    /// descendants).
    pub fn evictable_from(&self, id: NodeId, tier: Tier) -> bool {
        let n = self.node(id);
        n.tiers.contains(tier)
            && n.pins == 0
            && (n.tiers.count() > 1 || n.present_children == 0)
    }

    /// All nodes currently evictable from `tier` (the policy's
    /// candidate set). O(nodes) slab walk (§Perf iteration 2) — the hot
    /// path avoids even that via the victim index (§Perf iteration 3,
    /// EXPERIMENTS.md); this stays as the unfused reference oracle.
    pub fn eviction_candidates(&self, tier: Tier) -> Vec<NodeId> {
        self.ids_slab()
            .filter(|id| self.evictable_from(*id, tier))
            .collect()
    }

    /// Resident bytes per tier (for invariant checks; the engine keeps
    /// its own running counters). Slab walk, not a hash iteration.
    pub fn resident_bytes(&self, tier: Tier) -> u64 {
        self.ids_slab()
            .filter(|id| self.node(*id).tiers.contains(tier))
            .map(|id| self.node(id).bytes)
            .sum()
    }

    /// Iterate all live node ids (hash-map order; stable given the
    /// same op sequence).
    pub fn ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.index.values().copied()
    }

    /// Iterate live node ids in slab order — contiguous memory walk for
    /// hot scans (eviction victim selection).
    pub fn ids_slab(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.live
            .iter()
            .enumerate()
            .filter(|(_, l)| **l)
            .map(|(i, _)| NodeId(i as u32))
    }

    /// Validate structural invariants; returns an error string on the
    /// first violation. Used by tests and the mini-proptest harness.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (&key, &id) in &self.index {
            let n = self.node(id);
            if n.key != key {
                return Err(format!("index key mismatch for {key:?}"));
            }
            // chain presence
            if !n.tiers.is_empty() {
                if let Some(p) = n.parent {
                    if self.node(p).tiers.is_empty() {
                        return Err(format!(
                            "chain-presence violated: {:?} present, parent absent",
                            n.key
                        ));
                    }
                }
            }
            // present_children consistency
            let actual = self.children[id.0 as usize]
                .iter()
                .filter(|c| !self.node(**c).tiers.is_empty())
                .count() as u32;
            if actual != n.present_children {
                return Err(format!(
                    "present_children mismatch at {:?}: stored {} actual {}",
                    n.key, n.present_children, actual
                ));
            }
        }
        // pending/queued bookkeeping: each set bit corresponds to
        // exactly one queue entry (push only happens on a clear bit)
        for t in Tier::ALL {
            let bit = 1u8 << t.idx();
            let bits = self.queued.iter().filter(|q| **q & bit != 0).count();
            if bits != self.pending[t.idx()].len() {
                return Err(format!(
                    "pending/queued mismatch in {}: {bits} bits, {} entries",
                    t.name(),
                    self.pending[t.idx()].len()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};

    fn chain(n: usize) -> Vec<ChunkKey> {
        let mut keys = Vec::new();
        let mut parent = ChunkKey::ROOT;
        for i in 0..n {
            let k = chain_hash(parent, &[i as u32]);
            keys.push(k);
            parent = k;
        }
        keys
    }

    fn insert_chain(t: &mut PrefixTree, keys: &[ChunkKey], tier: Tier) -> Vec<NodeId> {
        let mut parent = None;
        let mut ids = Vec::new();
        for k in keys {
            let id = t.ensure(parent, *k, 100);
            t.add_residency(id, tier);
            ids.push(id);
            parent = Some(id);
        }
        ids
    }

    #[test]
    fn match_stops_at_first_absent() {
        let mut t = PrefixTree::new();
        let keys = chain(4);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        assert_eq!(t.match_chain(&keys).len(), 4);
        // drop residency of chunk 2 -> match stops there
        t.remove_residency(ids[3], Tier::Dram);
        t.remove_residency(ids[2], Tier::Dram);
        assert_eq!(t.match_chain(&keys).len(), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_single_nodes() {
        let mut t = PrefixTree::new();
        let a = chain(3);
        let mut b = a[..2].to_vec();
        b.push(chain_hash(a[1], &[99]));
        insert_chain(&mut t, &a, Tier::Dram);
        insert_chain(&mut t, &b, Tier::Dram);
        assert_eq!(t.len(), 4); // 2 shared + 2 distinct tails
        t.check_invariants().unwrap();
    }

    #[test]
    fn evictable_semantics() {
        let mut t = PrefixTree::new();
        let keys = chain(3);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        // middle node with DRAM-only copy and a present child: locked
        assert!(!t.evictable_from(ids[1], Tier::Dram));
        // leaf: evictable
        assert!(t.evictable_from(ids[2], Tier::Dram));
        // give middle node an SSD copy too: now its DRAM copy can go
        t.add_residency(ids[1], Tier::Ssd);
        assert!(t.evictable_from(ids[1], Tier::Dram));
        // ...and (symmetrically) so can the SSD copy while DRAM holds it
        assert!(t.evictable_from(ids[1], Tier::Ssd));
        // but once the DRAM copy is gone, the SSD copy is the last one
        // and the present child locks it in place
        t.remove_residency(ids[1], Tier::Dram);
        assert!(!t.evictable_from(ids[1], Tier::Ssd));
        // pinned leaf: not evictable
        t.pin(ids[2]);
        assert!(!t.evictable_from(ids[2], Tier::Dram));
        t.unpin(ids[2]);
        assert!(t.evictable_from(ids[2], Tier::Dram));
    }

    #[test]
    fn leaf_eviction_unlocks_parent() {
        // paper: "when C4 is evicted, its parent becomes a new leaf"
        let mut t = PrefixTree::new();
        let keys = chain(2);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        assert!(!t.evictable_from(ids[0], Tier::Dram));
        let gone = t.remove_residency(ids[1], Tier::Dram);
        assert!(gone);
        assert!(t.evictable_from(ids[0], Tier::Dram));
        t.check_invariants().unwrap();
    }

    #[test]
    fn eviction_candidates_only_leaves() {
        let mut t = PrefixTree::new();
        let keys = chain(4);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        let cands = t.eviction_candidates(Tier::Dram);
        assert_eq!(cands, vec![ids[3]]);
    }

    #[test]
    fn erase_and_slot_reuse() {
        let mut t = PrefixTree::new();
        let keys = chain(2);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        t.remove_residency(ids[1], Tier::Dram);
        t.erase(ids[1]);
        assert_eq!(t.len(), 1);
        assert!(t.get(keys[1]).is_none());
        // slot gets reused
        let k2 = chain_hash(keys[0], &[7]);
        let id2 = t.ensure(Some(ids[0]), k2, 50);
        assert_eq!(id2.0, ids[1].0);
        t.add_residency(id2, Tier::Dram);
        t.check_invariants().unwrap();
    }

    #[test]
    fn sweep_absent_collects_garbage() {
        let mut t = PrefixTree::new();
        let keys = chain(3);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        for id in ids.iter().rev() {
            t.remove_residency(*id, Tier::Dram);
        }
        t.sweep_absent();
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn touch_updates_recency_and_freq() {
        let mut t = PrefixTree::new();
        let keys = chain(1);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        let before = t.node(ids[0]).last_access;
        t.touch(ids[0]);
        let n = t.node(ids[0]);
        assert!(n.last_access > before);
        assert_eq!(n.freq, 1);
    }

    #[test]
    fn boost_is_monotone() {
        let mut t = PrefixTree::new();
        let keys = chain(1);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        t.boost(ids[0], 10);
        t.boost(ids[0], 5); // lower boost must not shrink protection
        assert_eq!(t.node(ids[0]).boost_until, 10);
    }

    #[test]
    fn resident_bytes_sums() {
        let mut t = PrefixTree::new();
        let keys = chain(3);
        insert_chain(&mut t, &keys, Tier::Dram);
        assert_eq!(t.resident_bytes(Tier::Dram), 300);
        assert_eq!(t.resident_bytes(Tier::Ssd), 0);
    }

    fn drain_pending(t: &mut PrefixTree, tier: Tier) -> Vec<NodeId> {
        let mut out = Vec::new();
        while let Some(id) = t.take_pending(tier) {
            out.push(id);
        }
        out
    }

    #[test]
    fn rank_events_bump_gen_and_queue_once() {
        let mut t = PrefixTree::new();
        let keys = chain(1);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        drain_pending(&mut t, Tier::Dram);
        let g0 = t.rank_gen(ids[0]);
        // several events before any drain: gen moves per event, but the
        // pending queue holds exactly one entry (the `queued` bitmask)
        t.touch(ids[0]);
        t.touch(ids[0]);
        t.pin(ids[0]);
        t.unpin(ids[0]);
        assert!(t.rank_gen(ids[0]) > g0);
        assert_eq!(drain_pending(&mut t, Tier::Dram), vec![ids[0]]);
        assert_eq!(t.pending_len(Tier::Dram), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn residency_changes_requeue_parent() {
        let mut t = PrefixTree::new();
        let keys = chain(2);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        drain_pending(&mut t, Tier::Dram);
        let pg = t.rank_gen(ids[0]);
        // evicting the leaf flips the parent to evictable: the parent
        // must be requeued so the index re-admits it
        t.remove_residency(ids[1], Tier::Dram);
        assert!(t.rank_gen(ids[0]) > pg);
        assert_eq!(drain_pending(&mut t, Tier::Dram), vec![ids[0]]);
        t.check_invariants().unwrap();
    }

    #[test]
    fn mark_queues_all_resident_tiers() {
        let mut t = PrefixTree::new();
        let keys = chain(1);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        t.add_residency(ids[0], Tier::Ssd);
        drain_pending(&mut t, Tier::Dram);
        drain_pending(&mut t, Tier::Ssd);
        t.touch(ids[0]);
        assert_eq!(drain_pending(&mut t, Tier::Dram), vec![ids[0]]);
        assert_eq!(drain_pending(&mut t, Tier::Ssd), vec![ids[0]]);
        assert_eq!(t.pending_len(Tier::Gpu), 0);
    }

    #[test]
    fn boost_expiry_requeues_on_clock_passing() {
        let mut t = PrefixTree::new();
        let keys = chain(1);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        let until = t.now() + 3;
        t.boost(ids[0], until);
        drain_pending(&mut t, Tier::Dram);
        // horizon not reached: nothing to requeue
        t.expire_boosts();
        assert_eq!(t.pending_len(Tier::Dram), 0);
        while t.now() < until {
            t.tick();
        }
        t.expire_boosts();
        assert_eq!(drain_pending(&mut t, Tier::Dram), vec![ids[0]]);
        // queue is drained: a second call is a no-op
        t.expire_boosts();
        assert_eq!(t.pending_len(Tier::Dram), 0);
    }

    #[test]
    fn erase_bumps_gen_for_slot_reuse() {
        let mut t = PrefixTree::new();
        let keys = chain(2);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        t.remove_residency(ids[1], Tier::Dram);
        drain_pending(&mut t, Tier::Dram);
        let g_dead = t.rank_gen(ids[1]);
        t.erase(ids[1]);
        // the freed slot's generation moved: entries stamped before the
        // erase can never validate against the slot's next occupant
        assert!(t.rank_gen(ids[1]) > g_dead);
        let k2 = chain_hash(keys[0], &[7]);
        let id2 = t.ensure(Some(ids[0]), k2, 50);
        assert_eq!(id2.0, ids[1].0);
        t.add_residency(id2, Tier::Dram);
        t.check_invariants().unwrap();
    }

    #[test]
    fn requeue_all_touches_every_live_node() {
        let mut t = PrefixTree::new();
        let keys = chain(3);
        let ids = insert_chain(&mut t, &keys, Tier::Dram);
        drain_pending(&mut t, Tier::Dram);
        t.requeue_all();
        let mut got = drain_pending(&mut t, Tier::Dram);
        got.sort();
        let mut want = ids.clone();
        want.sort();
        assert_eq!(got, want);
    }
}
