//! Eviction policies over the prefix tree's per-tier leaf candidates.
//!
//! * [`PolicyKind::Lru`] — plain least-recently-used over leaves (what
//!   vLLM's prefix cache and the CCache/SCCache baselines run).
//! * [`PolicyKind::LookaheadLru`] — the paper's contribution (§4.2):
//!   LRU that *skips* leaves whose chunks appear in pending requests in
//!   the waiting queue (their `boost_until` is ahead of the clock),
//!   falling back to plain LRU when every candidate is protected.
//! * [`PolicyKind::Fifo`] — insertion-order baseline.
//! * [`PolicyKind::Pgdsf`] — greedy-dual-size-frequency (the RAGCache
//!   baseline's eviction strategy), priority = freq·cost/size.

use crate::cache::prefix_tree::{NodeId, PrefixTree};
use crate::cache::tier::Tier;

/// Which eviction policy a cache engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Lru,
    LookaheadLru,
    Fifo,
    Pgdsf,
}

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Lru => "lru",
            PolicyKind::LookaheadLru => "lookahead-lru",
            PolicyKind::Fifo => "fifo",
            PolicyKind::Pgdsf => "pgdsf",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "lru" => Some(PolicyKind::Lru),
            "lookahead-lru" | "lookahead" => Some(PolicyKind::LookaheadLru),
            "fifo" => Some(PolicyKind::Fifo),
            "pgdsf" => Some(PolicyKind::Pgdsf),
            _ => None,
        }
    }

    /// Pick the victim among `candidates` (all evictable from `tier`).
    /// Returns None iff `candidates` is empty.
    pub fn pick_victim(
        self,
        tree: &PrefixTree,
        _tier: Tier,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        if candidates.is_empty() {
            return None;
        }
        let now = tree.now();
        match self {
            PolicyKind::Lru => candidates
                .iter()
                .copied()
                .min_by_key(|id| tree.node(*id).last_access),
            PolicyKind::LookaheadLru => {
                // Prefer unprotected leaves; the paper's example evicts
                // the second-oldest leaf C4 because the oldest, C2, is
                // referenced by a queued request.
                let unprotected = candidates
                    .iter()
                    .copied()
                    .filter(|id| tree.node(*id).boost_until <= now)
                    .min_by_key(|id| tree.node(*id).last_access);
                unprotected.or_else(|| {
                    // everything protected: fall back to plain LRU
                    candidates
                        .iter()
                        .copied()
                        .min_by_key(|id| tree.node(*id).last_access)
                })
            }
            PolicyKind::Fifo => candidates
                .iter()
                .copied()
                .min_by_key(|id| tree.node(*id).inserted_at),
            PolicyKind::Pgdsf => {
                // priority = freq * cost / size; cost ~ bytes (the KV
                // recompute cost is proportional to the chunk's tokens,
                // which is proportional to bytes at fixed chunk size),
                // so priority reduces to freq, tie-broken by recency.
                candidates.iter().copied().min_by(|a, b| {
                    let na = tree.node(*a);
                    let nb = tree.node(*b);
                    let pa = (na.freq + 1) as f64 / na.bytes.max(1) as f64;
                    let pb = (nb.freq + 1) as f64 / nb.bytes.max(1) as f64;
                    pa.partial_cmp(&pb)
                        .unwrap()
                        .then(na.last_access.cmp(&nb.last_access))
                })
            }
        }
    }
}

impl PolicyKind {
    /// Fused victim selection: a single allocation-free pass over the
    /// tree that filters evictability and tracks the policy minimum
    /// inline (§Perf iteration 1 — replaces collect-then-scan on the
    /// eviction hot path; `pick_victim` remains for candidate lists
    /// produced elsewhere).
    pub fn pick_victim_fused(self, tree: &PrefixTree, tier: Tier) -> Option<NodeId> {
        let now = tree.now();
        match self {
            PolicyKind::Lru => tree
                .ids_slab()
                .filter(|id| tree.evictable_from(*id, tier))
                .min_by_key(|id| tree.node(*id).last_access),
            PolicyKind::Fifo => tree
                .ids_slab()
                .filter(|id| tree.evictable_from(*id, tier))
                .min_by_key(|id| tree.node(*id).inserted_at),
            PolicyKind::Pgdsf => tree
                .ids_slab()
                .filter(|id| tree.evictable_from(*id, tier))
                .min_by(|a, b| {
                    let na = tree.node(*a);
                    let nb = tree.node(*b);
                    let pa = (na.freq + 1) as f64 / na.bytes.max(1) as f64;
                    let pb = (nb.freq + 1) as f64 / nb.bytes.max(1) as f64;
                    pa.partial_cmp(&pb)
                        .unwrap()
                        .then(na.last_access.cmp(&nb.last_access))
                }),
            PolicyKind::LookaheadLru => {
                // one pass, two minima: prefer the oldest unprotected
                // leaf, falling back to the oldest overall
                let mut best_unprot: Option<(u64, NodeId)> = None;
                let mut best_any: Option<(u64, NodeId)> = None;
                for id in tree.ids_slab() {
                    if !tree.evictable_from(id, tier) {
                        continue;
                    }
                    let n = tree.node(id);
                    let key = (n.last_access, id);
                    if best_any.map(|b| key < b).unwrap_or(true) {
                        best_any = Some(key);
                    }
                    if n.boost_until <= now
                        && best_unprot.map(|b| key < b).unwrap_or(true)
                    {
                        best_unprot = Some(key);
                    }
                }
                best_unprot.or(best_any).map(|(_, id)| id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};

    /// Three independent root-level leaves with controlled recency.
    fn three_leaves(tree: &mut PrefixTree) -> Vec<NodeId> {
        let mut ids = Vec::new();
        for i in 0..3u32 {
            let k = chain_hash(ChunkKey::ROOT, &[i]);
            let id = tree.ensure(None, k, 100);
            tree.add_residency(id, Tier::Dram);
            ids.push(id);
        }
        // recency order: ids[0] oldest, ids[2] newest
        for id in &ids {
            tree.touch(*id);
        }
        ids
    }

    #[test]
    fn lru_picks_oldest() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let v = PolicyKind::Lru.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn lookahead_skips_boosted_oldest() {
        // The Fig 7 walk-through: C2 (oldest) is boosted by the queue,
        // so the second-oldest C4 goes instead.
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let until = t.now() + 100;
        t.boost(ids[0], until);
        let v = PolicyKind::LookaheadLru.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[1]));
        // plain LRU would have evicted the boosted one
        let v = PolicyKind::Lru.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn lookahead_falls_back_when_all_protected() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let until = t.now() + 100;
        for id in &ids {
            t.boost(*id, until);
        }
        let v = PolicyKind::LookaheadLru.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0])); // oldest overall
    }

    #[test]
    fn expired_boost_no_longer_protects() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let until = t.now() + 1;
        t.boost(ids[0], until);
        t.tick();
        t.tick(); // clock passes the boost horizon
        let v = PolicyKind::LookaheadLru.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn fifo_ignores_recency() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        t.touch(ids[0]); // make the first-inserted the most recent
        let v = PolicyKind::Fifo.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
        let v = PolicyKind::Lru.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[1]));
    }

    #[test]
    fn pgdsf_prefers_cold_low_frequency() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        t.touch(ids[0]);
        t.touch(ids[0]); // hot
        let v = PolicyKind::Pgdsf.pick_victim(&t, Tier::Dram, &ids);
        assert_ne!(v, Some(ids[0]));
    }

    #[test]
    fn empty_candidates_is_none() {
        let t = PrefixTree::new();
        assert_eq!(PolicyKind::Lru.pick_victim(&t, Tier::Dram, &[]), None);
    }

    #[test]
    fn parse_round_trips() {
        for k in [PolicyKind::Lru, PolicyKind::LookaheadLru, PolicyKind::Fifo, PolicyKind::Pgdsf] {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(PolicyKind::parse("bogus"), None);
    }
}
