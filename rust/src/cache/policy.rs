//! Pluggable eviction policies over the prefix tree's per-tier leaf
//! candidates.
//!
//! The policy surface is an open, object-safe trait ([`EvictionPolicy`])
//! plus a name-based [`registry`]; the cache engine owns one boxed
//! policy and drives it through lifecycle hooks (`on_insert`/`on_hit`/
//! `on_evict`) over a per-node metadata slot ([`Node::policy_meta`]).
//! See the `cache` module docs for a guide to writing a custom policy.
//!
//! Registered policies:
//!
//! * `lru` — plain least-recently-used over leaves (what vLLM's prefix
//!   cache and the CCache/SCCache baselines run).
//! * `lookahead-lru` — the paper's contribution (§4.2): LRU that
//!   *skips* leaves whose chunks appear in pending requests in the
//!   waiting queue (their `boost_until` is ahead of the clock), falling
//!   back to plain LRU when every candidate is protected.
//! * `fifo` — insertion-order baseline.
//! * `pgdsf` — greedy-dual-size-frequency (the RAGCache baseline's
//!   eviction strategy), priority = freq·cost/size.
//! * `slru` — segmented LRU: chunks start probationary, a reuse hit
//!   promotes them to a protected segment; probation evicts first
//!   (scan-resistant for one-shot RAG corpora).
//! * `2q` — simplified 2Q: first touch lands in an A1 FIFO queue, a
//!   second touch moves the chunk to the main LRU queue; A1 drains
//!   first in insertion order.
//! * `lfuda` — LFU with dynamic aging: priority = freq + global age;
//!   the age rises to each victim's priority, so once-hot chunks cannot
//!   hold the cache forever (skewed multi-tenant traffic).
//! * `lookahead-slru` — hybrid of the paper's look-ahead protection and
//!   SLRU segmentation: queue-referenced chunks evict last, and within
//!   each protection class probation drains before the protected
//!   segment.
//!
//! [`Node::policy_meta`]: crate::cache::prefix_tree::Node

use crate::cache::prefix_tree::{NodeId, PrefixTree};
use crate::cache::tier::Tier;
use crate::cache::victim_index::VictimIndex;
use std::cmp::Ordering;

/// Total-order ranking key for victim selection: the candidate with the
/// *minimum* `(class, score, tie, NodeId)` is evicted next. `class`
/// partitions candidates into eviction bands (e.g. unprotected before
/// protected), `score` is a policy value within the band, `tie` is the
/// final deterministic tiebreak (usually a recency clock).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VictimRank {
    pub class: u8,
    pub score: f64,
    pub tie: u64,
}

impl VictimRank {
    /// Rank purely by a clock value (LRU/FIFO-style).
    pub fn recency(tie: u64) -> VictimRank {
        VictimRank { class: 0, score: 0.0, tie }
    }

    /// Rank by band, then clock.
    pub fn classed(class: u8, tie: u64) -> VictimRank {
        VictimRank { class, score: 0.0, tie }
    }

    /// Rank by a continuous score, then clock.
    pub fn scored(score: f64, tie: u64) -> VictimRank {
        VictimRank { class: 0, score, tie }
    }
}

/// The total victim order every selection path shares: `(class, score,
/// tie, NodeId)` lexicographically, minimum first. The trailing NodeId
/// makes it a strict total order (no ties), which the victim index
/// relies on: two heap entries compare equal only if they name the
/// same node.
pub fn rank_cmp(a: &(VictimRank, NodeId), b: &(VictimRank, NodeId)) -> Ordering {
    a.0.class
        .cmp(&b.0.class)
        .then(a.0.score.total_cmp(&b.0.score))
        .then(a.0.tie.cmp(&b.0.tie))
        .then(a.1.cmp(&b.1))
}

/// An eviction policy the cache engine can run. Object-safe: the engine
/// holds a `Box<dyn EvictionPolicy>` created by [`registry::parse`].
///
/// Implementors provide [`rank`](EvictionPolicy::rank); the two victim
/// selectors share it, which makes the fused (allocation-free) and
/// candidate-list paths agree by construction — a property the test
/// suite checks for every registered policy. Policies that keep state
/// do so in [`Node::policy_meta`] (per chunk, via the lifecycle hooks)
/// and/or in their own fields (global, e.g. LFUDA's age).
///
/// [`Node::policy_meta`]: crate::cache::prefix_tree::Node
pub trait EvictionPolicy: std::fmt::Debug + Send {
    /// Canonical (registry) name.
    fn name(&self) -> &'static str;

    /// Rank one evictable candidate; the minimum rank is evicted first.
    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank;

    /// Pick the victim among `candidates` (all evictable from `tier`).
    /// Returns None iff `candidates` is empty.
    fn pick_victim(
        &self,
        tree: &PrefixTree,
        _tier: Tier,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        candidates
            .iter()
            .copied()
            .map(|id| (self.rank(tree, id), id))
            .min_by(rank_cmp)
            .map(|(_, id)| id)
    }

    /// Fused victim selection: a single allocation-free pass over the
    /// tree slab that filters evictability and tracks the policy
    /// minimum inline (§Perf iteration 1 — replaces collect-then-scan
    /// on the eviction hot path; `pick_victim` remains for candidate
    /// lists produced elsewhere).
    fn pick_victim_fused(&self, tree: &PrefixTree, tier: Tier) -> Option<NodeId> {
        tree.ids_slab()
            .filter(|id| tree.evictable_from(*id, tier))
            .map(|id| (self.rank(tree, id), id))
            .min_by(rank_cmp)
            .map(|(_, id)| id)
    }

    /// Indexed victim selection (§Perf iteration 3): consult the
    /// engine's per-tier lazy rank heap instead of scanning the slab.
    /// Amortized O(log n) per pick; agrees with `pick_victim_fused` by
    /// construction because both rank through
    /// [`rank`](EvictionPolicy::rank) — the three-way parity proptest
    /// pins this for every registered policy. Override only to swap in
    /// a policy-specific ordered index; most policies (including all
    /// registered ones) use this default.
    fn pick_victim_indexed(
        &self,
        tree: &mut PrefixTree,
        tier: Tier,
        index: &mut VictimIndex,
    ) -> Option<NodeId> {
        index.pick(tree, tier, &|t, id| self.rank(t, id))
    }

    /// Whether this policy's ranks are safe for the incremental index:
    /// `rank` must be a pure function of the node's tracked inputs
    /// (recency, frequency, bytes, `policy_meta`, pins, residency),
    /// with clock dependence only through `boost_until > now()`
    /// comparisons. Policies that rank through hidden mutable state
    /// must return `false` (falling back to the fused scan) or call
    /// `CacheEngine::force_reindex` after out-of-band changes — see
    /// the `cache` module docs.
    fn indexable(&self) -> bool {
        true
    }

    /// A chunk became resident (first insertion or re-insertion after a
    /// full eviction). Runs after residency bookkeeping.
    fn on_insert(&mut self, _tree: &mut PrefixTree, _id: NodeId) {}

    /// A lookup matched this chunk. Runs after the tree's recency/
    /// frequency touch.
    fn on_hit(&mut self, _tree: &mut PrefixTree, _id: NodeId) {}

    /// This chunk was evicted from one tier (it may survive in others).
    fn on_evict(&mut self, _tree: &mut PrefixTree, _id: NodeId) {}
}

// ---------------------------------------------------------------------
// The paper's four policies, on the trait.
// ---------------------------------------------------------------------

/// Plain LRU: evict the least recently touched leaf.
#[derive(Clone, Copy, Debug, Default)]
pub struct Lru;

impl EvictionPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        VictimRank::recency(tree.node(id).last_access)
    }
}

/// The paper's look-ahead LRU (§4.2): prefer unprotected leaves — the
/// Fig 7 example evicts the second-oldest leaf C4 because the oldest,
/// C2, is referenced by a queued request — falling back to plain LRU
/// when every candidate is protected.
#[derive(Clone, Copy, Debug, Default)]
pub struct LookaheadLru;

impl EvictionPolicy for LookaheadLru {
    fn name(&self) -> &'static str {
        "lookahead-lru"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        let n = tree.node(id);
        let protected = n.boost_until > tree.now();
        VictimRank::classed(protected as u8, n.last_access)
    }
}

/// Insertion-order baseline.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl EvictionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        VictimRank::recency(tree.node(id).inserted_at)
    }
}

/// Greedy-dual-size-frequency (the RAGCache baseline): priority =
/// freq · cost / size; cost ~ bytes (KV recompute cost is proportional
/// to the chunk's tokens, which is proportional to bytes at fixed chunk
/// size), so priority reduces to freq/size, tie-broken by recency.
#[derive(Clone, Copy, Debug, Default)]
pub struct Pgdsf;

impl EvictionPolicy for Pgdsf {
    fn name(&self) -> &'static str {
        "pgdsf"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        let n = tree.node(id);
        let priority = (n.freq + 1) as f64 / n.bytes.max(1) as f64;
        VictimRank::scored(priority, n.last_access)
    }
}

// ---------------------------------------------------------------------
// New policies (this PR): SLRU, 2Q, LFUDA, look-ahead SLRU.
// ---------------------------------------------------------------------

/// Segment bit in `policy_meta` for the SLRU-family and 2Q policies:
/// 0 = probationary / A1, 1 = protected / Am.
const SEG_PROTECTED: u64 = 1;

/// Shared segment-bit lifecycle of the SLRU family (SLRU, 2Q,
/// look-ahead SLRU): enter on probation, a reuse hit earns protection,
/// a tier eviction demotes surviving copies back to probation. One
/// source of truth so the three policies cannot drift apart.
macro_rules! segment_lifecycle_hooks {
    () => {
        fn on_insert(&mut self, tree: &mut PrefixTree, id: NodeId) {
            tree.set_policy_meta(id, 0);
        }

        fn on_hit(&mut self, tree: &mut PrefixTree, id: NodeId) {
            tree.set_policy_meta(id, SEG_PROTECTED);
        }

        fn on_evict(&mut self, tree: &mut PrefixTree, id: NodeId) {
            tree.set_policy_meta(id, 0);
        }
    };
}

/// Segmented LRU. Insertions land in the probationary segment
/// (`policy_meta = 0`); a reuse hit promotes to the protected segment.
/// Probationary chunks evict first (oldest first), so a one-shot scan
/// cannot flush chunks with demonstrated reuse. A tier eviction demotes
/// surviving copies back to probation, making protection re-earned.
#[derive(Clone, Copy, Debug, Default)]
pub struct Slru;

impl EvictionPolicy for Slru {
    fn name(&self) -> &'static str {
        "slru"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        let n = tree.node(id);
        VictimRank::classed((n.policy_meta & SEG_PROTECTED) as u8, n.last_access)
    }

    segment_lifecycle_hooks!();
}

/// Simplified 2Q. Like SLRU, but the probationary queue (A1) drains in
/// *insertion* order — a FIFO of chunks seen exactly once — while the
/// main queue (Am) is LRU over chunks with repeated use.
#[derive(Clone, Copy, Debug, Default)]
pub struct TwoQ;

impl EvictionPolicy for TwoQ {
    fn name(&self) -> &'static str {
        "2q"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        let n = tree.node(id);
        let seg = n.policy_meta & SEG_PROTECTED;
        let tie = if seg == 0 { n.inserted_at } else { n.last_access };
        VictimRank::classed(seg as u8, tie)
    }

    segment_lifecycle_hooks!();
}

/// LFU with dynamic aging. Each chunk's cached priority
/// (`policy_meta`) is `freq + age` at its last touch; the global `age`
/// rises to every victim's priority, so chunks that were hot long ago
/// decay relative to fresh traffic instead of pinning the cache.
#[derive(Clone, Copy, Debug, Default)]
pub struct Lfuda {
    age: u64,
}

impl EvictionPolicy for Lfuda {
    fn name(&self) -> &'static str {
        "lfuda"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        let n = tree.node(id);
        VictimRank::scored(n.policy_meta as f64, n.last_access)
    }

    fn on_insert(&mut self, tree: &mut PrefixTree, id: NodeId) {
        let p = self.age + tree.node(id).freq + 1;
        tree.set_policy_meta(id, p);
    }

    fn on_hit(&mut self, tree: &mut PrefixTree, id: NodeId) {
        let p = self.age + tree.node(id).freq + 1;
        tree.set_policy_meta(id, p);
    }

    fn on_evict(&mut self, tree: &mut PrefixTree, id: NodeId) {
        self.age = self.age.max(tree.node(id).policy_meta);
    }
}

/// Look-ahead SLRU hybrid: the queue-driven boost protection of
/// `lookahead-lru` crossed with SLRU segmentation. Eviction preference
/// (first to last): unboosted probation, unboosted protected, boosted
/// probation, boosted protected — so queue-referenced chunks always
/// outlive unreferenced ones, and within each boost class the segment
/// that earned reuse survives longer.
#[derive(Clone, Copy, Debug, Default)]
pub struct LookaheadSlru;

impl EvictionPolicy for LookaheadSlru {
    fn name(&self) -> &'static str {
        "lookahead-slru"
    }

    fn rank(&self, tree: &PrefixTree, id: NodeId) -> VictimRank {
        let n = tree.node(id);
        let boosted = (n.boost_until > tree.now()) as u8;
        let seg = (n.policy_meta & SEG_PROTECTED) as u8;
        VictimRank::classed(boosted * 2 + seg, n.last_access)
    }

    segment_lifecycle_hooks!();
}

/// Name-based policy registry — the open extension point that replaced
/// the old closed `PolicyKind` enum. `parse` is case-insensitive.
pub mod registry {
    use super::*;

    /// Canonical names of every registered policy (what config
    /// validation errors list, and what the ablation sweeps iterate).
    pub const NAMES: [&str; 8] = [
        "lru",
        "lookahead-lru",
        "fifo",
        "pgdsf",
        "slru",
        "2q",
        "lfuda",
        "lookahead-slru",
    ];

    /// Create a fresh policy instance by name (case-insensitive;
    /// `lookahead` and `twoq` are accepted aliases). Returns None for
    /// unregistered names.
    pub fn parse(name: &str) -> Option<Box<dyn EvictionPolicy>> {
        let lower = name.to_ascii_lowercase();
        let policy: Box<dyn EvictionPolicy> = match lower.as_str() {
            "lru" => Box::new(Lru),
            "lookahead-lru" | "lookahead" => Box::new(LookaheadLru),
            "fifo" => Box::new(Fifo),
            "pgdsf" => Box::new(Pgdsf),
            "slru" => Box::new(Slru),
            "2q" | "twoq" => Box::new(TwoQ),
            "lfuda" => Box::new(Lfuda::default()),
            "lookahead-slru" => Box::new(LookaheadSlru),
            _ => return None,
        };
        Some(policy)
    }

    /// Comma-separated registered names (for error messages).
    pub fn names_joined() -> String {
        NAMES.join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};
    use crate::cache::engine::{CacheConfig, CacheEngine};
    use crate::util::proptest::{check, forall};

    fn policy(name: &str) -> Box<dyn EvictionPolicy> {
        registry::parse(name).unwrap()
    }

    /// Three independent root-level leaves with controlled recency.
    fn three_leaves(tree: &mut PrefixTree) -> Vec<NodeId> {
        let mut ids = Vec::new();
        for i in 0..3u32 {
            let k = chain_hash(ChunkKey::ROOT, &[i]);
            let id = tree.ensure(None, k, 100);
            tree.add_residency(id, Tier::Dram);
            ids.push(id);
        }
        // recency order: ids[0] oldest, ids[2] newest
        for id in &ids {
            tree.touch(*id);
        }
        ids
    }

    #[test]
    fn lru_picks_oldest() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let v = policy("lru").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn lookahead_skips_boosted_oldest() {
        // The Fig 7 walk-through: C2 (oldest) is boosted by the queue,
        // so the second-oldest C4 goes instead.
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let until = t.now() + 100;
        t.boost(ids[0], until);
        let v = policy("lookahead-lru").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[1]));
        // plain LRU would have evicted the boosted one
        let v = policy("lru").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn lookahead_falls_back_when_all_protected() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let until = t.now() + 100;
        for id in &ids {
            t.boost(*id, until);
        }
        let v = policy("lookahead-lru").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0])); // oldest overall
    }

    #[test]
    fn expired_boost_no_longer_protects() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        let until = t.now() + 1;
        t.boost(ids[0], until);
        t.tick();
        t.tick(); // clock passes the boost horizon
        let v = policy("lookahead-lru").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn fifo_ignores_recency() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        t.touch(ids[0]); // make the first-inserted the most recent
        let v = policy("fifo").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
        let v = policy("lru").pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[1]));
    }

    #[test]
    fn pgdsf_prefers_cold_low_frequency() {
        let mut t = PrefixTree::new();
        let ids = three_leaves(&mut t);
        t.touch(ids[0]);
        t.touch(ids[0]); // hot
        let v = policy("pgdsf").pick_victim(&t, Tier::Dram, &ids);
        assert_ne!(v, Some(ids[0]));
    }

    #[test]
    fn slru_evicts_probation_before_protected() {
        let mut t = PrefixTree::new();
        let mut p = policy("slru");
        let ids = three_leaves(&mut t);
        for id in &ids {
            p.on_insert(&mut t, *id);
        }
        // hit the oldest: it moves to the protected segment
        t.touch(ids[0]);
        p.on_hit(&mut t, ids[0]);
        // probation (ids[1], ids[2]) drains first, oldest first —
        // plain LRU would now evict ids[1] too, but for a different
        // reason; distinguish by protecting everything except ids[2]
        t.touch(ids[1]);
        p.on_hit(&mut t, ids[1]);
        let v = p.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[2]), "sole probationary leaf goes first");
        // all protected: falls back to LRU among protected
        t.touch(ids[2]);
        p.on_hit(&mut t, ids[2]);
        let v = p.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[0]));
    }

    #[test]
    fn slru_eviction_demotes_survivors() {
        let mut t = PrefixTree::new();
        let mut p = policy("slru");
        let ids = three_leaves(&mut t);
        p.on_insert(&mut t, ids[0]);
        p.on_hit(&mut t, ids[0]);
        assert_eq!(t.node(ids[0]).policy_meta, 1);
        p.on_evict(&mut t, ids[0]);
        assert_eq!(t.node(ids[0]).policy_meta, 0);
    }

    #[test]
    fn twoq_a1_drains_fifo_first() {
        let mut t = PrefixTree::new();
        let mut p = policy("2q");
        let ids = three_leaves(&mut t);
        for id in &ids {
            p.on_insert(&mut t, *id);
        }
        // promote ids[0] to Am; touch ids[1] WITHOUT a hit event (e.g.
        // a boost-path touch) so it stays in A1
        t.touch(ids[0]);
        p.on_hit(&mut t, ids[0]);
        t.touch(ids[1]);
        // A1 = {ids[1], ids[2]} drains in insertion order despite
        // ids[1] being more recently touched
        let v = p.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[1]));
    }

    #[test]
    fn lfuda_age_lets_new_traffic_displace_old_hot_chunks() {
        let mut t = PrefixTree::new();
        let mut p = policy("lfuda");
        let ids = three_leaves(&mut t);
        for id in &ids {
            p.on_insert(&mut t, *id);
        }
        // make ids[0] hot: freq climbs to 4, priority = freq + 1 = 5
        for _ in 0..3 {
            t.touch(ids[0]);
            p.on_hit(&mut t, ids[0]);
        }
        // a cold chunk (priority 2) is the victim, never the hot one
        let v = p.pick_victim(&t, Tier::Dram, &ids).unwrap();
        assert_ne!(v, ids[0]);
        // evicting it raises the global age to its priority (2), so the
        // NEXT insertion starts at priority age+1 = 3 — two hits away
        // from the old hot chunk instead of four
        p.on_evict(&mut t, v);
        let k = chain_hash(ChunkKey::ROOT, &[99]);
        let fresh = t.ensure(None, k, 100);
        t.add_residency(fresh, Tier::Dram);
        p.on_insert(&mut t, fresh);
        assert_eq!(t.node(fresh).policy_meta, 3);
    }

    #[test]
    fn lookahead_slru_boost_dominates_segment() {
        let mut t = PrefixTree::new();
        let mut p = policy("lookahead-slru");
        let ids = three_leaves(&mut t);
        for id in &ids {
            p.on_insert(&mut t, *id);
        }
        // ids[0]: boosted probation; ids[1]: unboosted protected;
        // ids[2]: unboosted probation
        t.boost(ids[0], t.now() + 100);
        t.touch(ids[1]);
        p.on_hit(&mut t, ids[1]);
        // order out: ids[2] (unboosted probation), ids[1] (unboosted
        // protected), ids[0] (boosted)
        let v = p.pick_victim(&t, Tier::Dram, &ids);
        assert_eq!(v, Some(ids[2]));
        let rest = [ids[0], ids[1]];
        let v = p.pick_victim(&t, Tier::Dram, &rest);
        assert_eq!(v, Some(ids[1]));
    }

    #[test]
    fn empty_candidates_is_none() {
        let t = PrefixTree::new();
        assert_eq!(policy("lru").pick_victim(&t, Tier::Dram, &[]), None);
    }

    #[test]
    fn registry_round_trips_and_is_case_insensitive() {
        for name in registry::NAMES {
            let p = registry::parse(name).expect(name);
            assert_eq!(p.name(), name);
            let upper = name.to_ascii_uppercase();
            assert_eq!(registry::parse(&upper).unwrap().name(), name);
        }
        assert_eq!(registry::parse("lookahead").unwrap().name(), "lookahead-lru");
        assert_eq!(registry::parse("twoq").unwrap().name(), "2q");
        assert!(registry::parse("bogus").is_none());
        assert!(registry::names_joined().contains("slru"));
    }

    /// Drive a cache engine with `ops` — inserts across tiers, lookups,
    /// boosts, pins/unpins, promotes/demotes and explicit evictions —
    /// so hooks fire, metadata accumulates, boost horizons expire, and
    /// the victim index piles up stale generation-stamped entries.
    /// After every op, for every registered policy and every tier, all
    /// three victim paths must agree:
    ///
    ///   indexed (lazy rank heap) == fused (slab scan)
    ///                            == unfused (candidate list)
    ///
    /// The fused scan is the reference oracle; this is the parity
    /// contract both hot paths rely on.
    #[test]
    fn prop_indexed_fused_unfused_victim_parity() {
        fn chain_of(tag: u32, n: usize) -> Vec<ChunkKey> {
            let mut keys = Vec::new();
            let mut parent = ChunkKey::ROOT;
            for i in 0..n {
                let k = chain_hash(parent, &[tag, i as u32]);
                keys.push(k);
                parent = k;
            }
            keys
        }

        for (pi, name) in registry::NAMES.iter().enumerate() {
            forall(
                0x9A117 + pi as u64,
                40,
                |rng| {
                    let n = 3 + rng.below(40) as usize;
                    (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
                },
                |ops| {
                    let mut e = CacheEngine::new(CacheConfig {
                        chunk_tokens: 4,
                        gpu_capacity: 300,
                        dram_capacity: 500,
                        ssd_capacity: 800,
                        policy: name.to_string(),
                    });
                    let chains: Vec<Vec<ChunkKey>> =
                        (0..6).map(|t| chain_of(t, 1 + (t as usize % 4))).collect();
                    // LIFO of pins we own, so unpin never underflows
                    let mut pinned: Vec<NodeId> = Vec::new();
                    for op in ops {
                        let chain = &chains[(op % 6) as usize];
                        let tier = Tier::ALL[((op >> 4) % 3) as usize];
                        match (op >> 8) % 8 {
                            0 | 1 => {
                                let mut parent = None;
                                for k in chain {
                                    match e.insert(parent, *k, 100, tier) {
                                        Some(id) => parent = Some(id),
                                        None => break,
                                    }
                                }
                            }
                            2 => {
                                e.lookup(chain);
                            }
                            3 => {
                                e.boost_chain(chain, (op >> 16) % 64);
                            }
                            4 => {
                                e.evict_one(tier);
                            }
                            5 => {
                                // pin the deepest present chunk (what
                                // the scheduler does around decode)
                                if let Some(&id) = e.tree.match_chain(chain).last() {
                                    e.tree.pin(id);
                                    pinned.push(id);
                                }
                            }
                            6 => {
                                if let Some(id) = pinned.pop() {
                                    e.tree.unpin(id);
                                }
                            }
                            _ => {
                                if (op >> 16) % 2 == 0 {
                                    // prefetcher path: SSD-only -> DRAM
                                    for id in e.prefetch_targets(chain) {
                                        e.promote(id, Tier::Dram);
                                    }
                                } else {
                                    let present = e.tree.match_chain(chain);
                                    for id in present {
                                        if e.tree.evictable_from(id, tier) {
                                            e.demote(id, tier);
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                        for t in Tier::ALL {
                            let fused = e.policy.pick_victim_fused(&e.tree, t);
                            let cands = e.tree.eviction_candidates(t);
                            let unfused = e.policy.pick_victim(&e.tree, t, &cands);
                            let indexed = {
                                let CacheEngine { policy, tree, victim_index, .. } = &mut e;
                                policy.pick_victim_indexed(tree, t, victim_index)
                            };
                            if fused != unfused || fused != indexed {
                                return Err(format!(
                                    "{name}: indexed {indexed:?} / fused {fused:?} / \
                                     unfused {unfused:?} over {} candidates in {}",
                                    cands.len(),
                                    t.name()
                                ));
                            }
                        }
                    }
                    check(true, "")
                },
            );
        }
    }
}
