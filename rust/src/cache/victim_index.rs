//! Incremental victim selection (§Perf iteration 3, EXPERIMENTS.md).
//!
//! The fused scan (`EvictionPolicy::pick_victim_fused`) walks every
//! live slab slot per eviction — O(n), fine at paper scale (a few
//! thousand chunks), quadratic pain during an insert burst on a
//! million-chunk tree. This module replaces the scan with per-tier
//! **lazy min-heaps** keyed by [`VictimRank`]:
//!
//! * Every heap entry is stamped with the node's *rank generation* at
//!   push time ([`PrefixTree::rank_gen`]). Any event that can change a
//!   node's rank or evictability bumps the generation (see
//!   `PrefixTree::mark`), so a mismatched entry is provably stale and
//!   is discarded when it surfaces at the top of the heap.
//! * Nodes whose rank inputs changed sit in the tree's per-tier
//!   `pending` queues (O(1) per event, deduplicated by a bitmask).
//!   [`VictimIndex::pick`] drains the queue — pushing fresh entries for
//!   nodes that are currently evictable — then peeks past stale tops.
//! * Boost expiry is the one rank change driven purely by the clock;
//!   `PrefixTree::expire_boosts` converts it into ordinary marks before
//!   each pick.
//!
//! The invariant that makes lazy deletion sound: **a generation-valid
//! entry's stored rank equals the node's true current rank**, because
//! every rank input feeds the generation. Stale entries may shadow the
//! heap top, but each is popped exactly once (amortized O(log n) per
//! rank event), and the heap is rebuilt from the slab whenever dead
//! weight exceeds twice the live-node count.
//!
//! `pick` *peeks* rather than pops: the entry for the returned victim
//! stays in the heap, and the eviction that follows bumps the node's
//! generation (residency change), turning that entry stale. This keeps
//! the index correct even if the caller ignores the returned victim.

use crate::cache::policy::{rank_cmp, VictimRank};
use crate::cache::prefix_tree::{NodeId, PrefixTree};
use crate::cache::tier::Tier;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One heap slot: a candidate with the rank and generation it had when
/// pushed. Ordering is by rank only (reversed, so `BinaryHeap`'s max
/// heap yields the minimum rank); the generation is payload.
#[derive(Clone, Copy, Debug)]
struct HeapEntry {
    rank: VictimRank,
    id: NodeId,
    gen_stamp: u64,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smallest rank at the top of the max-heap.
        // rank_cmp is a total order with the id tiebreak, so two
        // entries compare Equal only when they refer to the same node.
        rank_cmp(&(other.rank, other.id), &(self.rank, self.id))
    }
}

/// Per-tier lazy rank heaps. Owned by `CacheEngine`, consulted through
/// `EvictionPolicy::pick_victim_indexed`; all consistency bookkeeping
/// lives in [`PrefixTree`] so callers that mutate the tree directly
/// (scheduler pins, prefetcher promotes) keep the index honest for
/// free.
#[derive(Debug, Default)]
pub struct VictimIndex {
    heaps: [BinaryHeap<HeapEntry>; 3],
    /// Stale entries discarded at pick time (observability).
    pub stale_discarded: u64,
    /// Full heap rebuilds triggered by the dead-weight bound.
    pub compactions: u64,
}

impl VictimIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Entries (live + stale) currently held for `tier`.
    pub fn len(&self, tier: Tier) -> usize {
        self.heaps[tier.idx()].len()
    }

    pub fn is_empty(&self) -> bool {
        self.heaps.iter().all(|h| h.is_empty())
    }

    /// Drop all entries. The tree's pending queues are *not* touched;
    /// pair with [`PrefixTree::requeue_all`] to rebuild (that is what
    /// `CacheEngine::force_reindex` does).
    pub fn clear(&mut self) {
        for h in &mut self.heaps {
            h.clear();
        }
    }

    /// Select the minimum-rank node evictable from `tier`, or `None`
    /// if nothing is evictable. `rank` is the policy's rank function;
    /// amortized O(log n) per call against O(n) for the fused scan.
    pub fn pick(
        &mut self,
        tree: &mut PrefixTree,
        tier: Tier,
        rank: &dyn Fn(&PrefixTree, NodeId) -> VictimRank,
    ) -> Option<NodeId> {
        // 1. turn clock-driven boost expiries into ordinary marks
        tree.expire_boosts();
        // 2. (re-)index everything whose rank inputs changed
        while let Some(id) = tree.take_pending(tier) {
            if tree.evictable_from(id, tier) {
                self.heaps[tier.idx()].push(HeapEntry {
                    rank: rank(tree, id),
                    id,
                    gen_stamp: tree.rank_gen(id),
                });
            }
            // not evictable: any older entry for it is already stale
            // (the event that disqualified it bumped the generation)
        }
        // 3. bound dead weight: rebuild from the slab when stale
        //    entries dominate
        if self.heaps[tier.idx()].len() > 2 * tree.len() + 64 {
            self.compact(tree, tier, rank);
        }
        // 4. peek past stale tops to the first generation-valid entry
        loop {
            let top = *self.heaps[tier.idx()].peek()?;
            if top.gen_stamp == tree.rank_gen(top.id) && tree.evictable_from(top.id, tier) {
                return Some(top.id);
            }
            self.heaps[tier.idx()].pop();
            self.stale_discarded += 1;
        }
    }

    /// Rebuild `tier`'s heap from the live slab, discarding all stale
    /// entries at once. O(n); amortized away by the 2n + 64 trigger.
    fn compact(
        &mut self,
        tree: &PrefixTree,
        tier: Tier,
        rank: &dyn Fn(&PrefixTree, NodeId) -> VictimRank,
    ) {
        let entries: Vec<HeapEntry> = tree
            .ids_slab()
            .filter(|id| tree.evictable_from(*id, tier))
            .map(|id| HeapEntry {
                rank: rank(tree, id),
                id,
                gen_stamp: tree.rank_gen(id),
            })
            .collect();
        self.heaps[tier.idx()] = BinaryHeap::from(entries);
        self.compactions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};
    use crate::cache::policy::registry;

    fn lru_rank() -> impl Fn(&PrefixTree, NodeId) -> VictimRank {
        let p = registry::parse("lru").unwrap();
        move |t: &PrefixTree, id: NodeId| p.rank(t, id)
    }

    /// n independent root-level leaves, all DRAM-resident.
    fn leaves(n: usize) -> (PrefixTree, Vec<NodeId>) {
        let mut t = PrefixTree::new();
        let mut ids = Vec::new();
        for i in 0..n {
            let id = t.ensure(None, chain_hash(ChunkKey::ROOT, &[i as u32]), 100);
            t.add_residency(id, Tier::Dram);
            ids.push(id);
        }
        (t, ids)
    }

    #[test]
    fn picks_lru_minimum() {
        let (mut t, ids) = leaves(4);
        let rank = lru_rank();
        let mut idx = VictimIndex::new();
        // ids[0] is oldest by insertion order
        assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), Some(ids[0]));
        // touching it moves it to the back; pick follows
        t.touch(ids[0]);
        assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), Some(ids[1]));
    }

    #[test]
    fn peek_semantics_survive_ignored_picks() {
        let (mut t, ids) = leaves(3);
        let rank = lru_rank();
        let mut idx = VictimIndex::new();
        // picking twice without evicting returns the same victim
        let a = idx.pick(&mut t, Tier::Dram, &rank);
        let b = idx.pick(&mut t, Tier::Dram, &rank);
        assert_eq!(a, b);
        assert_eq!(a, Some(ids[0]));
    }

    #[test]
    fn eviction_invalidates_the_picked_entry() {
        let (mut t, ids) = leaves(3);
        let rank = lru_rank();
        let mut idx = VictimIndex::new();
        let v = idx.pick(&mut t, Tier::Dram, &rank).unwrap();
        assert_eq!(v, ids[0]);
        t.remove_residency(v, Tier::Dram); // bumps gen -> entry stale
        assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), Some(ids[1]));
        assert!(idx.stale_discarded > 0);
    }

    #[test]
    fn pinned_nodes_are_skipped_until_unpinned() {
        let (mut t, ids) = leaves(2);
        let rank = lru_rank();
        let mut idx = VictimIndex::new();
        t.pin(ids[0]);
        assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), Some(ids[1]));
        t.unpin(ids[0]);
        assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), Some(ids[0]));
    }

    #[test]
    fn empty_tier_returns_none() {
        let (mut t, _) = leaves(2);
        let rank = lru_rank();
        let mut idx = VictimIndex::new();
        assert_eq!(idx.pick(&mut t, Tier::Gpu, &rank), None);
    }

    #[test]
    fn compaction_bounds_heap_size() {
        let (mut t, ids) = leaves(8);
        let rank = lru_rank();
        let mut idx = VictimIndex::new();
        // repeatedly re-rank the *newest* node: its stale entries sink
        // to the bottom of the heap and never surface at peek time, so
        // only the dead-weight bound can reclaim them
        let hot = ids[7];
        for _ in 0..200 {
            t.touch(hot);
            assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), Some(ids[0]));
        }
        assert!(idx.compactions >= 1, "dead weight never compacted");
        assert!(idx.len(Tier::Dram) <= 2 * t.len() + 64);
        // index still agrees with a fresh fused answer
        let p = registry::parse("lru").unwrap();
        assert_eq!(idx.pick(&mut t, Tier::Dram, &rank), p.pick_victim_fused(&t, Tier::Dram));
    }

    #[test]
    fn matches_fused_scan_for_every_policy_on_a_static_tree() {
        for name in registry::NAMES {
            let p = registry::parse(name).unwrap();
            let (mut t, ids) = leaves(6);
            // vary the rank inputs a bit
            t.touch(ids[2]);
            t.touch(ids[4]);
            t.touch(ids[2]);
            t.boost(ids[0], t.now() + 100);
            let mut idx = VictimIndex::new();
            let rank = |tr: &PrefixTree, id: NodeId| p.rank(tr, id);
            assert_eq!(
                idx.pick(&mut t, Tier::Dram, &rank),
                p.pick_victim_fused(&t, Tier::Dram),
                "policy {name}"
            );
        }
    }
}
