//! Chunk identity: fixed-size token chunks addressed by a *prefix-chain
//! hash* (paper §4.2 / Algorithm 1's `HashPrefix(chunk, parent)`).
//!
//! KV caches are position-dependent, so a chunk's identity must encode
//! its entire prefix: two chunks with identical token ids but different
//! parents hash to different keys (the paper's C6 vs C8 example). The
//! chain hash gives exactly that: `key_i = H(key_{i-1} ‖ tokens_i)`.

use crate::util::rng::splitmix64;

/// Identity of one KV chunk (prefix-chain hash).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkKey(pub u64);

impl ChunkKey {
    /// The root of every chain (empty prefix).
    pub const ROOT: ChunkKey = ChunkKey(0x9E37_79B9_7F4A_7C15);
}

/// FNV-1a-then-mix over the parent key and the chunk's token ids.
/// splitmix finalization keeps avalanche good enough for tree fanout.
pub fn chain_hash(parent: ChunkKey, tokens: &[u32]) -> ChunkKey {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325 ^ parent.0.rotate_left(17);
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x1000_0000_01B3);
    }
    let mut s = h;
    ChunkKey(splitmix64(&mut s))
}

/// A request's token sequence split into chunk-granularity pieces, with
/// chain keys precomputed (Algorithm 1's `Chunkify`).
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkedSeq {
    /// Chain key of each *full* chunk, in order.
    pub keys: Vec<ChunkKey>,
    /// Tokens per chunk (all `chunk_size` — the trailing partial chunk
    /// is NOT cacheable and is excluded; see `tail_tokens`).
    pub chunk_tokens: usize,
    /// Number of tokens beyond the last full chunk (computed fresh each
    /// time, never cached — matches vLLM block-aligned prefix caching).
    pub tail_tokens: usize,
    /// Total tokens in the original sequence.
    pub total_tokens: usize,
}

impl ChunkedSeq {
    /// Split `tokens` into `chunk_size`-token chunks, chaining hashes.
    pub fn new(tokens: &[u32], chunk_size: usize) -> ChunkedSeq {
        assert!(chunk_size > 0);
        let full = tokens.len() / chunk_size;
        let mut keys = Vec::with_capacity(full);
        let mut parent = ChunkKey::ROOT;
        for c in 0..full {
            let key = chain_hash(parent, &tokens[c * chunk_size..(c + 1) * chunk_size]);
            keys.push(key);
            parent = key;
        }
        ChunkedSeq {
            keys,
            chunk_tokens: chunk_size,
            tail_tokens: tokens.len() - full * chunk_size,
            total_tokens: tokens.len(),
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.keys.len()
    }

    /// Tokens covered by the first `n` chunks.
    pub fn tokens_in(&self, n: usize) -> usize {
        n.min(self.keys.len()) * self.chunk_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_depends_on_parent() {
        // Same token ids, different prefix -> different identity
        // (paper's C6 vs C8).
        let toks = [1u32, 2, 3, 4];
        let a = chain_hash(ChunkKey::ROOT, &toks);
        let b = chain_hash(a, &toks);
        assert_ne!(a, b);
    }

    #[test]
    fn chain_hash_deterministic() {
        let toks = [9u32, 8, 7];
        assert_eq!(chain_hash(ChunkKey::ROOT, &toks),
                   chain_hash(ChunkKey::ROOT, &toks));
    }

    #[test]
    fn chain_hash_sensitive_to_each_token() {
        let a = chain_hash(ChunkKey::ROOT, &[1, 2, 3]);
        let b = chain_hash(ChunkKey::ROOT, &[1, 2, 4]);
        let c = chain_hash(ChunkKey::ROOT, &[0, 2, 3]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn chunkify_splits_and_chains() {
        let tokens: Vec<u32> = (0..10).collect();
        let cs = ChunkedSeq::new(&tokens, 4);
        assert_eq!(cs.n_chunks(), 2);
        assert_eq!(cs.tail_tokens, 2);
        assert_eq!(cs.total_tokens, 10);
        // chain property: prefix determines keys
        let cs2 = ChunkedSeq::new(&(0..8).collect::<Vec<u32>>(), 4);
        assert_eq!(cs.keys, cs2.keys);
    }

    #[test]
    fn shared_prefix_shares_keys() {
        // [doc1:doc2] vs [doc1:doc3] share exactly doc1's chunks.
        let mut a: Vec<u32> = (0..8).collect();
        a.extend(100..108);
        let mut b: Vec<u32> = (0..8).collect();
        b.extend(200..208);
        let ca = ChunkedSeq::new(&a, 4);
        let cb = ChunkedSeq::new(&b, 4);
        assert_eq!(ca.keys[..2], cb.keys[..2]);
        assert_ne!(ca.keys[2], cb.keys[2]);
        assert_ne!(ca.keys[3], cb.keys[3]); // divergence propagates
    }

    #[test]
    fn tokens_in_clamps() {
        let cs = ChunkedSeq::new(&(0..16).collect::<Vec<u32>>(), 4);
        assert_eq!(cs.tokens_in(2), 8);
        assert_eq!(cs.tokens_in(99), 16);
    }

    #[test]
    fn empty_and_short_sequences() {
        let cs = ChunkedSeq::new(&[], 4);
        assert_eq!(cs.n_chunks(), 0);
        let cs = ChunkedSeq::new(&[1, 2], 4);
        assert_eq!(cs.n_chunks(), 0);
        assert_eq!(cs.tail_tokens, 2);
    }
}
