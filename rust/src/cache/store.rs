//! Chunk *data* stores for the real (PJRT) serving path: the metadata
//! engine decides placement; these hold the actual KV bytes.
//!
//! * [`MemStore`] — DRAM tier: an in-process byte map.
//! * [`FileStore`] — SSD tier: one file per chunk under a spill
//!   directory (the e2e example uses a real directory, giving real
//!   read/write latency on the test machine's disk).

use crate::cache::chunk::ChunkKey;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::PathBuf;

/// Uniform interface over chunk-byte storage backends.
pub trait ChunkStore: Send {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()>;
    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>>;
    fn delete(&mut self, key: ChunkKey) -> Result<()>;
    fn contains(&self, key: ChunkKey) -> bool;
    fn bytes_used(&self) -> u64;
}

/// In-memory store (the DRAM tier of the real path).
#[derive(Debug, Default)]
pub struct MemStore {
    map: HashMap<ChunkKey, Vec<u8>>,
    bytes: u64,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ChunkStore for MemStore {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        if let Some(old) = self.map.insert(key, data.to_vec()) {
            self.bytes -= old.len() as u64;
        }
        self.bytes += data.len() as u64;
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        Ok(self.map.get(&key).cloned())
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.len() as u64;
        }
        Ok(())
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.map.contains_key(&key)
    }

    fn bytes_used(&self) -> u64 {
        self.bytes
    }
}

/// One-file-per-chunk store (the SSD tier of the real path).
#[derive(Debug)]
pub struct FileStore {
    dir: PathBuf,
    index: HashMap<ChunkKey, u64>, // key -> byte length
    bytes: u64,
}

impl FileStore {
    /// Open (or create) a spill directory. Existing `*.kv` files from a
    /// previous process are adopted into the index, so restarts see the
    /// true SSD occupancy instead of undercounting `bytes_used` and
    /// over-admitting spills; leftover `*.kv.tmp` files are torn writes
    /// from a crash and are swept.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {dir:?}"))?;
        let mut index = HashMap::new();
        let mut bytes = 0u64;
        for entry in std::fs::read_dir(&dir)
            .with_context(|| format!("scanning spill dir {dir:?}"))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.ends_with(".kv.tmp") {
                let _ = std::fs::remove_file(entry.path());
                continue;
            }
            let Some(hex) = name.strip_suffix(".kv") else { continue };
            let Ok(key) = u64::from_str_radix(hex, 16) else { continue };
            let len = entry.metadata()?.len();
            index.insert(ChunkKey(key), len);
            bytes += len;
        }
        Ok(FileStore { dir, index, bytes })
    }

    fn path(&self, key: ChunkKey) -> PathBuf {
        self.dir.join(format!("{:016x}.kv", key.0))
    }

    /// Keys currently indexed (restart reconciliation / store sweeps).
    pub fn keys(&self) -> Vec<ChunkKey> {
        self.index.keys().copied().collect()
    }
}

impl ChunkStore for FileStore {
    /// Crash-safe write: bytes go to a `.kv.tmp` sidecar first and are
    /// renamed into place, so a torn write can never leave a truncated
    /// chunk that a later `get` would return as valid KV bytes.
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        let path = self.path(key);
        let tmp = path.with_extension("kv.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(data)?;
            f.sync_all().ok(); // best effort on test filesystems
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {tmp:?} into place"))?;
        if let Some(old) = self.index.insert(key, data.len() as u64) {
            self.bytes -= old;
        }
        self.bytes += data.len() as u64;
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        if !self.index.contains_key(&key) {
            return Ok(None);
        }
        let path = self.path(key);
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening {path:?}"))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Ok(Some(buf))
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        if let Some(old) = self.index.remove(&key) {
            self.bytes -= old;
            let _ = std::fs::remove_file(self.path(key));
        }
        Ok(())
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.contains_key(&key)
    }

    fn bytes_used(&self) -> u64 {
        self.bytes
    }
}

impl Drop for FileStore {
    fn drop(&mut self) {
        // best-effort cleanup of spill files
        for key in self.index.keys().copied().collect::<Vec<_>>() {
            let _ = std::fs::remove_file(self.path(key));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u64) -> ChunkKey {
        ChunkKey(i)
    }

    fn exercise(store: &mut dyn ChunkStore) {
        assert!(!store.contains(key(1)));
        store.put(key(1), &[1, 2, 3]).unwrap();
        store.put(key(2), &[4; 10]).unwrap();
        assert_eq!(store.bytes_used(), 13);
        assert_eq!(store.get(key(1)).unwrap().unwrap(), vec![1, 2, 3]);
        assert!(store.get(key(9)).unwrap().is_none());
        // overwrite adjusts accounting
        store.put(key(1), &[9; 5]).unwrap();
        assert_eq!(store.bytes_used(), 15);
        store.delete(key(1)).unwrap();
        assert!(!store.contains(key(1)));
        assert_eq!(store.bytes_used(), 10);
        store.delete(key(42)).unwrap(); // deleting absent is a no-op
    }

    #[test]
    fn mem_store_basics() {
        let mut s = MemStore::new();
        exercise(&mut s);
    }

    #[test]
    fn file_store_basics() {
        let dir = std::env::temp_dir().join(format!("pcr-store-test-{}", std::process::id()));
        let mut s = FileStore::new(&dir).unwrap();
        exercise(&mut s);
        drop(s);
        // spill files cleaned up
        let remaining = std::fs::read_dir(&dir)
            .map(|d| d.count())
            .unwrap_or(0);
        assert_eq!(remaining, 0);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn file_store_reconciles_on_restart() {
        let dir = std::env::temp_dir().join(format!("pcr-store-restart-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.put(key(1), &[1; 100]).unwrap();
        s.put(key(2), &[2; 50]).unwrap();
        // simulate a crash: skip Drop so the spill files survive
        std::mem::forget(s);
        // ...including a torn write that never got renamed into place
        std::fs::write(dir.join("00000000000000ff.kv.tmp"), [0u8; 7]).unwrap();
        let s2 = FileStore::new(&dir).unwrap();
        assert_eq!(s2.bytes_used(), 150, "restart must adopt existing spill bytes");
        assert!(s2.contains(key(1)) && s2.contains(key(2)));
        assert_eq!(s2.get(key(2)).unwrap().unwrap(), vec![2u8; 50]);
        assert_eq!(s2.keys().len(), 2);
        assert!(
            !dir.join("00000000000000ff.kv.tmp").exists(),
            "torn writes must be swept, not adopted"
        );
        drop(s2);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn put_is_atomic_rename_no_tmp_left() {
        let dir = std::env::temp_dir().join(format!("pcr-store-atomic-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        for i in 0..8 {
            s.put(key(i), &[i as u8; 64]).unwrap();
        }
        let tmp_left = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .count();
        assert_eq!(tmp_left, 0);
        // overwrite goes through the same rename path
        s.put(key(3), &[9; 16]).unwrap();
        assert_eq!(s.get(key(3)).unwrap().unwrap(), vec![9u8; 16]);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn file_store_round_trips_large_chunk() {
        let dir = std::env::temp_dir().join(format!("pcr-store-big-{}", std::process::id()));
        let mut s = FileStore::new(&dir).unwrap();
        let data: Vec<u8> = (0..1_000_000u32).map(|x| x as u8).collect();
        s.put(key(7), &data).unwrap();
        assert_eq!(s.get(key(7)).unwrap().unwrap(), data);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }
}
