//! Chunk *data* stores for the real (PJRT) serving path: the metadata
//! engine decides placement; these hold the actual KV bytes.
//!
//! * [`MemStore`] — DRAM tier: an in-process byte map.
//! * [`FileStore`] — SSD tier: one file per chunk under a spill
//!   directory (the e2e example uses a real directory, giving real
//!   read/write latency on the test machine's disk).

use crate::cache::chunk::ChunkKey;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::PathBuf;

/// Uniform interface over chunk-byte storage backends.
pub trait ChunkStore: Send {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()>;
    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>>;
    fn delete(&mut self, key: ChunkKey) -> Result<()>;
    fn contains(&self, key: ChunkKey) -> bool;
    fn bytes_used(&self) -> u64;
}

/// In-memory store (the DRAM tier of the real path).
#[derive(Debug, Default)]
pub struct MemStore {
    map: HashMap<ChunkKey, Vec<u8>>,
    bytes: u64,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ChunkStore for MemStore {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        if let Some(old) = self.map.insert(key, data.to_vec()) {
            self.bytes -= old.len() as u64;
        }
        self.bytes += data.len() as u64;
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        Ok(self.map.get(&key).cloned())
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.len() as u64;
        }
        Ok(())
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.map.contains_key(&key)
    }

    fn bytes_used(&self) -> u64 {
        self.bytes
    }
}

/// One-file-per-chunk store (the SSD tier of the real path).
#[derive(Debug)]
pub struct FileStore {
    dir: PathBuf,
    index: HashMap<ChunkKey, u64>, // key -> byte length
    bytes: u64,
}

impl FileStore {
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {dir:?}"))?;
        Ok(FileStore {
            dir,
            index: HashMap::new(),
            bytes: 0,
        })
    }

    fn path(&self, key: ChunkKey) -> PathBuf {
        self.dir.join(format!("{:016x}.kv", key.0))
    }
}

impl ChunkStore for FileStore {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        let path = self.path(key);
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {path:?}"))?;
        f.write_all(data)?;
        if let Some(old) = self.index.insert(key, data.len() as u64) {
            self.bytes -= old;
        }
        self.bytes += data.len() as u64;
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        if !self.index.contains_key(&key) {
            return Ok(None);
        }
        let path = self.path(key);
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening {path:?}"))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Ok(Some(buf))
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        if let Some(old) = self.index.remove(&key) {
            self.bytes -= old;
            let _ = std::fs::remove_file(self.path(key));
        }
        Ok(())
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.contains_key(&key)
    }

    fn bytes_used(&self) -> u64 {
        self.bytes
    }
}

impl Drop for FileStore {
    fn drop(&mut self) {
        // best-effort cleanup of spill files
        for key in self.index.keys().copied().collect::<Vec<_>>() {
            let _ = std::fs::remove_file(self.path(key));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u64) -> ChunkKey {
        ChunkKey(i)
    }

    fn exercise(store: &mut dyn ChunkStore) {
        assert!(!store.contains(key(1)));
        store.put(key(1), &[1, 2, 3]).unwrap();
        store.put(key(2), &[4; 10]).unwrap();
        assert_eq!(store.bytes_used(), 13);
        assert_eq!(store.get(key(1)).unwrap().unwrap(), vec![1, 2, 3]);
        assert!(store.get(key(9)).unwrap().is_none());
        // overwrite adjusts accounting
        store.put(key(1), &[9; 5]).unwrap();
        assert_eq!(store.bytes_used(), 15);
        store.delete(key(1)).unwrap();
        assert!(!store.contains(key(1)));
        assert_eq!(store.bytes_used(), 10);
        store.delete(key(42)).unwrap(); // deleting absent is a no-op
    }

    #[test]
    fn mem_store_basics() {
        let mut s = MemStore::new();
        exercise(&mut s);
    }

    #[test]
    fn file_store_basics() {
        let dir = std::env::temp_dir().join(format!("pcr-store-test-{}", std::process::id()));
        let mut s = FileStore::new(&dir).unwrap();
        exercise(&mut s);
        drop(s);
        // spill files cleaned up
        let remaining = std::fs::read_dir(&dir)
            .map(|d| d.count())
            .unwrap_or(0);
        assert_eq!(remaining, 0);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn file_store_round_trips_large_chunk() {
        let dir = std::env::temp_dir().join(format!("pcr-store-big-{}", std::process::id()));
        let mut s = FileStore::new(&dir).unwrap();
        let data: Vec<u8> = (0..1_000_000u32).map(|x| x as u8).collect();
        s.put(key(7), &data).unwrap();
        assert_eq!(s.get(key(7)).unwrap().unwrap(), data);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }
}
