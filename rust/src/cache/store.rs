//! Chunk *data* stores for the real (PJRT) serving path: the metadata
//! engine decides placement; these hold the actual KV bytes.
//!
//! * [`MemStore`] — DRAM tier: an in-process byte map.
//! * [`FileStore`] — SSD tier: one file per chunk under a spill
//!   directory (the e2e example uses a real directory, giving real
//!   read/write latency on the test machine's disk).
//!
//! # Integrity
//!
//! [`FileStore`] appends an 8-byte little-endian FxHash trailer to
//! every `.kv` file on [`ChunkStore::put`] and verifies it on every
//! [`ChunkStore::get`] and on restart reconcile. A mismatch means the
//! bytes at rest were corrupted (bit rot, torn overwrite, hostile
//! edit): the file is *quarantined* — removed from disk and counted in
//! [`StoreStats::checksum_failures`] — and the read reports a miss so
//! the caller falls back to the always-correct recompute path instead
//! of decoding from garbage KV state. The trailer is excluded from
//! `bytes_used` accounting, which tracks logical payload bytes only.

use crate::cache::chunk::ChunkKey;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Byte length of the FxHash integrity trailer on each `.kv` file.
pub const CHECKSUM_LEN: u64 = 8;

/// FxHash64 of a chunk payload — the integrity trailer value.
pub fn chunk_checksum(data: &[u8]) -> u64 {
    use std::hash::Hasher;
    let mut h = crate::util::fxhash::FxHasher::default();
    h.write(data);
    h.finish()
}

/// Thread-safe counters for failures stores used to swallow silently.
///
/// Cloning shares the underlying counters, so a snapshot handle can be
/// taken before moving the store behind a lock.
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    inner: Arc<StoreStatsInner>,
}

#[derive(Debug, Default)]
struct StoreStatsInner {
    fsync_errors: AtomicU64,
    delete_errors: AtomicU64,
    checksum_failures: AtomicU64,
    lost_files: AtomicU64,
}

impl StoreStats {
    /// `sync_all` failures on put (data may not survive power loss).
    pub fn fsync_errors(&self) -> u64 {
        self.inner.fsync_errors.load(Ordering::Relaxed)
    }

    /// `remove_file` failures on delete (other than already-absent).
    pub fn delete_errors(&self) -> u64 {
        self.inner.delete_errors.load(Ordering::Relaxed)
    }

    /// Integrity-trailer mismatches; each one quarantined a file.
    pub fn checksum_failures(&self) -> u64 {
        self.inner.checksum_failures.load(Ordering::Relaxed)
    }

    /// Indexed files that vanished from disk before a read.
    pub fn lost_files(&self) -> u64 {
        self.inner.lost_files.load(Ordering::Relaxed)
    }

    /// Sum of all error counters (the `store_errors` metric).
    pub fn total(&self) -> u64 {
        self.fsync_errors() + self.delete_errors() + self.checksum_failures() + self.lost_files()
    }
}

/// Uniform interface over chunk-byte storage backends.
pub trait ChunkStore: Send {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()>;
    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>>;
    fn delete(&mut self, key: ChunkKey) -> Result<()>;
    fn contains(&self, key: ChunkKey) -> bool;
    fn bytes_used(&self) -> u64;
}

/// In-memory store (the DRAM tier of the real path).
#[derive(Debug, Default)]
pub struct MemStore {
    map: HashMap<ChunkKey, Vec<u8>>,
    bytes: u64,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ChunkStore for MemStore {
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        if let Some(old) = self.map.insert(key, data.to_vec()) {
            self.bytes -= old.len() as u64;
        }
        self.bytes += data.len() as u64;
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        Ok(self.map.get(&key).cloned())
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.len() as u64;
        }
        Ok(())
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.map.contains_key(&key)
    }

    fn bytes_used(&self) -> u64 {
        self.bytes
    }
}

/// One-file-per-chunk store (the SSD tier of the real path).
#[derive(Debug)]
pub struct FileStore {
    dir: PathBuf,
    index: HashMap<ChunkKey, u64>, // key -> payload byte length (trailer excluded)
    bytes: u64,
    persist: bool,
    stats: StoreStats,
}

impl FileStore {
    /// Open (or create) a spill directory. Existing `*.kv` files from a
    /// previous process are checksum-verified and adopted into the
    /// index, so restarts see the true SSD occupancy instead of
    /// undercounting `bytes_used` and over-admitting spills; leftover
    /// `*.kv.tmp` files are torn writes from a crash and are swept, and
    /// files whose integrity trailer does not match are quarantined
    /// (removed, counted) rather than adopted.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating spill dir {dir:?}"))?;
        let stats = StoreStats::default();
        let mut index = HashMap::new();
        let mut bytes = 0u64;
        for entry in
            std::fs::read_dir(&dir).with_context(|| format!("scanning spill dir {dir:?}"))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.ends_with(".kv.tmp") {
                let _ = std::fs::remove_file(entry.path());
                continue;
            }
            let Some(hex) = name.strip_suffix(".kv") else { continue };
            let Ok(key) = u64::from_str_radix(hex, 16) else { continue };
            let Ok(raw) = std::fs::read(entry.path()) else {
                stats.inner.lost_files.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            match verify_trailer(&raw) {
                Some(payload_len) => {
                    index.insert(ChunkKey(key), payload_len as u64);
                    bytes += payload_len as u64;
                }
                None => {
                    // corrupted at rest: sweep, never adopt
                    let _ = std::fs::remove_file(entry.path());
                    stats.inner.checksum_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(FileStore { dir, index, bytes, persist: false, stats })
    }

    /// Keep spill files on [`Drop`] so a later process can reconcile
    /// them (real deployments); default is to sweep them (tests).
    pub fn set_persist(&mut self, persist: bool) {
        self.persist = persist;
    }

    /// Handle onto the store's error counters (shared, thread-safe).
    pub fn stats(&self) -> StoreStats {
        self.stats.clone()
    }

    fn path(&self, key: ChunkKey) -> PathBuf {
        self.dir.join(format!("{:016x}.kv", key.0))
    }

    /// Keys currently indexed (restart reconciliation / store sweeps).
    pub fn keys(&self) -> Vec<ChunkKey> {
        self.index.keys().copied().collect()
    }
}

/// Split a raw file image into payload + trailer and verify the
/// checksum. Returns the payload length, or `None` if the image is
/// truncated or the trailer mismatches.
fn verify_trailer(raw: &[u8]) -> Option<usize> {
    let n = raw.len().checked_sub(CHECKSUM_LEN as usize)?;
    let want = u64::from_le_bytes(raw[n..].try_into().ok()?);
    (chunk_checksum(&raw[..n]) == want).then_some(n)
}

impl ChunkStore for FileStore {
    /// Crash-safe write: payload + integrity trailer go to a `.kv.tmp`
    /// sidecar first and are renamed into place, so a torn write can
    /// never leave a truncated chunk that a later `get` would return as
    /// valid KV bytes.
    fn put(&mut self, key: ChunkKey, data: &[u8]) -> Result<()> {
        let path = self.path(key);
        let tmp = path.with_extension("kv.tmp");
        {
            let mut f =
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(data)?;
            f.write_all(&chunk_checksum(data).to_le_bytes())?;
            if f.sync_all().is_err() {
                // data may not survive power loss; visible, not fatal
                self.stats.inner.fsync_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        std::fs::rename(&tmp, &path).with_context(|| format!("renaming {tmp:?} into place"))?;
        if let Some(old) = self.index.insert(key, data.len() as u64) {
            self.bytes -= old;
        }
        self.bytes += data.len() as u64;
        Ok(())
    }

    /// Checksum-verified read. A vanished file or a trailer mismatch is
    /// reported as a *miss* (`Ok(None)`), never as stale bytes: the
    /// corrupted file is quarantined off disk and counted, and the
    /// caller recomputes the chunk.
    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>> {
        if !self.index.contains_key(&key) {
            return Ok(None);
        }
        let path = self.path(key);
        let mut f = match std::fs::File::open(&path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // indexed but gone: permanent loss, degrade to a miss
                self.stats.inner.lost_files.fetch_add(1, Ordering::Relaxed);
                return Ok(None);
            }
            Err(e) => {
                return Err(anyhow::Error::from(e)).with_context(|| format!("opening {path:?}"))
            }
        };
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        match verify_trailer(&buf) {
            Some(payload_len) => {
                buf.truncate(payload_len);
                Ok(Some(buf))
            }
            None => {
                // quarantine: drop the poisoned file so it is never
                // re-read or re-adopted, and report a miss
                let _ = std::fs::remove_file(&path);
                self.stats.inner.checksum_failures.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    fn delete(&mut self, key: ChunkKey) -> Result<()> {
        if let Some(old) = self.index.remove(&key) {
            self.bytes -= old;
            if let Err(e) = std::fs::remove_file(self.path(key)) {
                // already-absent is expected after a quarantine; any
                // other failure leaks a spill file — count it
                if e.kind() != std::io::ErrorKind::NotFound {
                    self.stats.inner.delete_errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(())
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.contains_key(&key)
    }

    fn bytes_used(&self) -> u64 {
        self.bytes
    }
}

impl Drop for FileStore {
    fn drop(&mut self) {
        if self.persist {
            return; // deployment mode: leave files for restart reconcile
        }
        // best-effort cleanup of spill files
        for key in self.index.keys().copied().collect::<Vec<_>>() {
            let _ = std::fs::remove_file(self.path(key));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u64) -> ChunkKey {
        ChunkKey(i)
    }

    fn exercise(store: &mut dyn ChunkStore) {
        assert!(!store.contains(key(1)));
        store.put(key(1), &[1, 2, 3]).unwrap();
        store.put(key(2), &[4; 10]).unwrap();
        assert_eq!(store.bytes_used(), 13);
        assert_eq!(store.get(key(1)).unwrap().unwrap(), vec![1, 2, 3]);
        assert!(store.get(key(9)).unwrap().is_none());
        // overwrite adjusts accounting
        store.put(key(1), &[9; 5]).unwrap();
        assert_eq!(store.bytes_used(), 15);
        store.delete(key(1)).unwrap();
        assert!(!store.contains(key(1)));
        assert_eq!(store.bytes_used(), 10);
        store.delete(key(42)).unwrap(); // deleting absent is a no-op
    }

    #[test]
    fn mem_store_basics() {
        let mut s = MemStore::new();
        exercise(&mut s);
    }

    #[test]
    fn file_store_basics() {
        let dir = std::env::temp_dir().join(format!("pcr-store-test-{}", std::process::id()));
        let mut s = FileStore::new(&dir).unwrap();
        exercise(&mut s);
        drop(s);
        // spill files cleaned up
        let remaining = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        assert_eq!(remaining, 0);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn file_store_reconciles_on_restart() {
        let dir = std::env::temp_dir().join(format!("pcr-store-restart-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.put(key(1), &[1; 100]).unwrap();
        s.put(key(2), &[2; 50]).unwrap();
        // simulate a crash: skip Drop so the spill files survive
        std::mem::forget(s);
        // ...including a torn write that never got renamed into place
        std::fs::write(dir.join("00000000000000ff.kv.tmp"), [0u8; 7]).unwrap();
        let s2 = FileStore::new(&dir).unwrap();
        assert_eq!(s2.bytes_used(), 150, "restart must adopt existing spill bytes");
        assert!(s2.contains(key(1)) && s2.contains(key(2)));
        assert_eq!(s2.get(key(2)).unwrap().unwrap(), vec![2u8; 50]);
        assert_eq!(s2.keys().len(), 2);
        assert!(
            !dir.join("00000000000000ff.kv.tmp").exists(),
            "torn writes must be swept, not adopted"
        );
        drop(s2);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn put_is_atomic_rename_no_tmp_left() {
        let dir = std::env::temp_dir().join(format!("pcr-store-atomic-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        for i in 0..8 {
            s.put(key(i), &[i as u8; 64]).unwrap();
        }
        let tmp_left = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .count();
        assert_eq!(tmp_left, 0);
        // overwrite goes through the same rename path
        s.put(key(3), &[9; 16]).unwrap();
        assert_eq!(s.get(key(3)).unwrap().unwrap(), vec![9u8; 16]);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn file_store_round_trips_large_chunk() {
        let dir = std::env::temp_dir().join(format!("pcr-store-big-{}", std::process::id()));
        let mut s = FileStore::new(&dir).unwrap();
        let data: Vec<u8> = (0..1_000_000u32).map(|x| x as u8).collect();
        s.put(key(7), &data).unwrap();
        assert_eq!(s.get(key(7)).unwrap().unwrap(), data);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn checksum_detects_bit_flip_and_quarantines() {
        let dir = std::env::temp_dir().join(format!("pcr-store-flip-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.put(key(5), &[7; 32]).unwrap();
        let path = dir.join(format!("{:016x}.kv", 5));
        let mut raw = std::fs::read(&path).unwrap();
        assert_eq!(raw.len(), 32 + CHECKSUM_LEN as usize, "trailer appended");
        raw[3] ^= 0x40; // flip one bit in the payload
        std::fs::write(&path, &raw).unwrap();
        assert!(s.get(key(5)).unwrap().is_none(), "corrupted read must miss");
        assert_eq!(s.stats().checksum_failures(), 1);
        assert!(!path.exists(), "corrupted file must be quarantined off disk");
        // clean re-put over the quarantined slot round-trips again
        s.put(key(5), &[8; 16]).unwrap();
        assert_eq!(s.get(key(5)).unwrap().unwrap(), vec![8u8; 16]);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn checksum_detects_truncation() {
        let dir = std::env::temp_dir().join(format!("pcr-store-trunc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.put(key(6), &[3; 64]).unwrap();
        let path = dir.join(format!("{:016x}.kv", 6));
        let raw = std::fs::read(&path).unwrap();
        std::fs::write(&path, &raw[..raw.len() / 2]).unwrap();
        assert!(s.get(key(6)).unwrap().is_none());
        assert_eq!(s.stats().checksum_failures(), 1);
        // a file truncated below the trailer length is also rejected
        s.put(key(7), &[4; 8]).unwrap();
        let p7 = dir.join(format!("{:016x}.kv", 7));
        std::fs::write(&p7, [1u8, 2]).unwrap();
        assert!(s.get(key(7)).unwrap().is_none());
        assert_eq!(s.stats().checksum_failures(), 2);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn restart_sweeps_corrupted_files_not_adopts() {
        let dir = std::env::temp_dir().join(format!("pcr-store-rsweep-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.put(key(1), &[1; 40]).unwrap();
        s.put(key(2), &[2; 60]).unwrap();
        std::mem::forget(s);
        // corrupt one file at rest before the "restart"
        let p1 = dir.join(format!("{:016x}.kv", 1));
        let mut raw = std::fs::read(&p1).unwrap();
        raw[0] ^= 0xff;
        std::fs::write(&p1, &raw).unwrap();
        let s2 = FileStore::new(&dir).unwrap();
        assert!(!s2.contains(key(1)), "corrupted file must not be adopted");
        assert!(s2.contains(key(2)));
        assert_eq!(s2.bytes_used(), 60);
        assert_eq!(s2.stats().checksum_failures(), 1);
        assert!(!p1.exists(), "corrupted file must be swept on reconcile");
        drop(s2);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn lost_file_reads_as_miss_and_is_counted() {
        let dir = std::env::temp_dir().join(format!("pcr-store-lost-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.put(key(9), &[5; 24]).unwrap();
        std::fs::remove_file(dir.join(format!("{:016x}.kv", 9))).unwrap();
        assert!(s.get(key(9)).unwrap().is_none());
        assert_eq!(s.stats().lost_files(), 1);
        // deleting the now-absent file is not a delete error
        s.delete(key(9)).unwrap();
        assert_eq!(s.stats().delete_errors(), 0);
        assert_eq!(s.stats().total(), 1);
        drop(s);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn persist_mode_keeps_files_on_drop() {
        let dir = std::env::temp_dir().join(format!("pcr-store-persist-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = FileStore::new(&dir).unwrap();
        s.set_persist(true);
        s.put(key(1), &[1; 20]).unwrap();
        s.put(key(2), &[2; 30]).unwrap();
        drop(s);
        // files survived Drop; a restart adopts them
        let s2 = FileStore::new(&dir).unwrap();
        assert_eq!(s2.bytes_used(), 50);
        assert_eq!(s2.get(key(1)).unwrap().unwrap(), vec![1u8; 20]);
        drop(s2); // persist off by default: second drop sweeps
        let remaining = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        assert_eq!(remaining, 0);
        let _ = std::fs::remove_dir(&dir);
    }
}
