//! Storage tiers (GPU HBM / CPU DRAM / SSD) and residency sets.

/// One of the three storage tiers of the paper's cache hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    Gpu = 0,
    Dram = 1,
    Ssd = 2,
}

impl Tier {
    pub const ALL: [Tier; 3] = [Tier::Gpu, Tier::Dram, Tier::Ssd];

    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Gpu => "gpu",
            Tier::Dram => "dram",
            Tier::Ssd => "ssd",
        }
    }
}

/// Bitset of tiers a chunk is resident in.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierSet(u8);

impl TierSet {
    pub const EMPTY: TierSet = TierSet(0);

    pub fn single(t: Tier) -> TierSet {
        TierSet(1 << t.idx())
    }

    pub fn contains(self, t: Tier) -> bool {
        self.0 & (1 << t.idx()) != 0
    }

    pub fn insert(&mut self, t: Tier) {
        self.0 |= 1 << t.idx();
    }

    pub fn remove(&mut self, t: Tier) {
        self.0 &= !(1 << t.idx());
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    pub fn count(self) -> u32 {
        self.0.count_ones()
    }

    /// Fastest tier the chunk is resident in (GPU < DRAM < SSD).
    pub fn fastest(self) -> Option<Tier> {
        Tier::ALL.into_iter().find(|t| self.contains(*t))
    }

    pub fn iter(self) -> impl Iterator<Item = Tier> {
        Tier::ALL.into_iter().filter(move |t| self.contains(*t))
    }
}

/// Byte-accounted capacity of one tier.
#[derive(Clone, Copy, Debug)]
pub struct TierUsage {
    pub capacity: u64,
    pub used: u64,
}

impl TierUsage {
    pub fn new(capacity: u64) -> Self {
        TierUsage { capacity, used: 0 }
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used)
    }

    pub fn fits(&self, bytes: u64) -> bool {
        self.used + bytes <= self.capacity
    }

    pub fn add(&mut self, bytes: u64) {
        self.used += bytes;
        debug_assert!(self.used <= self.capacity, "tier over capacity");
    }

    pub fn sub(&mut self, bytes: u64) {
        debug_assert!(self.used >= bytes, "tier usage underflow");
        self.used = self.used.saturating_sub(bytes);
    }

    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.used as f64 / self.capacity as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tierset_ops() {
        let mut s = TierSet::EMPTY;
        assert!(s.is_empty());
        s.insert(Tier::Dram);
        s.insert(Tier::Ssd);
        assert!(s.contains(Tier::Dram));
        assert!(!s.contains(Tier::Gpu));
        assert_eq!(s.count(), 2);
        assert_eq!(s.fastest(), Some(Tier::Dram));
        s.remove(Tier::Dram);
        assert_eq!(s.fastest(), Some(Tier::Ssd));
        s.remove(Tier::Ssd);
        assert!(s.is_empty());
        assert_eq!(s.fastest(), None);
    }

    #[test]
    fn tierset_iter_in_speed_order() {
        let mut s = TierSet::EMPTY;
        s.insert(Tier::Ssd);
        s.insert(Tier::Gpu);
        let v: Vec<Tier> = s.iter().collect();
        assert_eq!(v, vec![Tier::Gpu, Tier::Ssd]);
    }

    #[test]
    fn usage_accounting() {
        let mut u = TierUsage::new(100);
        assert!(u.fits(100));
        u.add(60);
        assert_eq!(u.free(), 40);
        assert!(!u.fits(41));
        u.sub(10);
        assert_eq!(u.used, 50);
        assert!((u.utilization() - 0.5).abs() < 1e-12);
    }
}
