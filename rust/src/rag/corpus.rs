//! Synthetic retrieval corpus with Zipf-skewed topicality.
//!
//! Substitutes the paper's Wikipedia corpus (DESIGN.md §Substitutions):
//! what the cache experiments actually depend on is (a) deterministic
//! document token sequences, (b) realistic document lengths, and (c) a
//! skewed popularity distribution so the same documents recur across
//! queries at the paper's repetition ratios (~40% / ~35%).
//!
//! Documents are generated as token-id sequences directly (the
//! tokenizer is exercised separately and in the e2e example); each
//! document belongs to a topic cluster and its embedding (rag::embed)
//! reflects both topic and content, so nearest-neighbour retrieval for
//! a topic-focused query returns topically-related docs.

use crate::util::rng::{Rng, Zipf};

/// One retrievable document.
#[derive(Clone, Debug)]
pub struct Document {
    pub id: u32,
    pub topic: u32,
    pub tokens: Vec<u32>,
}

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub n_docs: usize,
    pub n_topics: usize,
    pub vocab: u32,
    /// Document length distribution: mean ± jitter (tokens).
    pub mean_doc_tokens: usize,
    pub doc_tokens_jitter: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_docs: 2_000,
            n_topics: 64,
            vocab: 2_048,
            mean_doc_tokens: 3_300, // 2 docs + query ≈ 6.8k tokens (paper)
            doc_tokens_jitter: 0.2,
            seed: 7,
        }
    }
}

/// The corpus plus its popularity model.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub docs: Vec<Document>,
    pub config: CorpusConfig,
    topic_zipf: Zipf,
}

impl Corpus {
    pub fn generate(config: CorpusConfig) -> Corpus {
        assert!(config.n_docs > 0 && config.n_topics > 0);
        let mut rng = Rng::new(config.seed);
        let mut docs = Vec::with_capacity(config.n_docs);
        for id in 0..config.n_docs {
            let topic = rng.below(config.n_topics as u64) as u32;
            let len = (config.mean_doc_tokens as f64
                * (1.0 + config.doc_tokens_jitter * (rng.f64() * 2.0 - 1.0)))
                .max(16.0) as usize;
            // Topic-conditioned token stream: half the tokens come from
            // a topic-specific band of the vocabulary, half are global.
            let band = config.vocab / config.n_topics.max(1) as u32;
            let topic_lo = 256 + (topic * band) % (config.vocab - 256).max(1);
            let mut tokens = Vec::with_capacity(len);
            for _ in 0..len {
                let t = if rng.chance(0.8) {
                    topic_lo + rng.below(band.max(1) as u64) as u32
                } else {
                    rng.below(config.vocab as u64) as u32
                };
                tokens.push(t.min(config.vocab - 1));
            }
            docs.push(Document {
                id: id as u32,
                topic,
                tokens,
            });
        }
        // Zipf over topics: a few topics get most queries — that is
        // what produces the paper's document repetition ratios.
        let topic_zipf = Zipf::new(config.n_topics, 1.0);
        Corpus {
            docs,
            config,
            topic_zipf,
        }
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    pub fn doc(&self, id: u32) -> &Document {
        &self.docs[id as usize]
    }

    /// Sample a query topic (Zipf-skewed) for workload generation.
    pub fn sample_topic(&self, rng: &mut Rng) -> u32 {
        // map zipf rank -> topic id via a fixed permutation (identity is
        // fine: topics are symmetric by construction)
        self.topic_zipf.sample(rng) as u32
    }

    /// Synthesize a query token sequence about `topic`.
    pub fn sample_query(&self, rng: &mut Rng, topic: u32, len: usize) -> Vec<u32> {
        let band = self.config.vocab / self.config.n_topics.max(1) as u32;
        let topic_lo = 256 + (topic * band) % (self.config.vocab - 256).max(1);
        (0..len)
            .map(|_| {
                let t = if rng.chance(0.9) {
                    topic_lo + rng.below(band.max(1) as u64) as u32
                } else {
                    rng.below(self.config.vocab as u64) as u32
                };
                t.min(self.config.vocab - 1)
            })
            .collect()
    }

    /// Total corpus tokens (the paper quotes ~5B for Wikipedia; ours is
    /// scaled down but the cache-to-corpus ratio is configured to match
    /// the same pressure regime).
    pub fn total_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.tokens.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Corpus {
        Corpus::generate(CorpusConfig {
            n_docs: 100,
            n_topics: 8,
            vocab: 2048,
            mean_doc_tokens: 200,
            doc_tokens_jitter: 0.2,
            seed: 1,
        })
    }

    #[test]
    fn deterministic_generation() {
        let a = small();
        let b = small();
        for (x, y) in a.docs.iter().zip(&b.docs) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.topic, y.topic);
        }
    }

    #[test]
    fn doc_lengths_near_mean() {
        let c = small();
        let mean: f64 = c.docs.iter().map(|d| d.tokens.len() as f64).sum::<f64>()
            / c.len() as f64;
        assert!((mean - 200.0).abs() < 30.0, "mean={mean}");
    }

    #[test]
    fn tokens_in_vocab() {
        let c = small();
        for d in &c.docs {
            for &t in &d.tokens {
                assert!(t < 2048);
            }
        }
    }

    #[test]
    fn topic_sampling_is_skewed() {
        let c = small();
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; 8];
        for _ in 0..8000 {
            counts[c.sample_topic(&mut rng) as usize] += 1;
        }
        // Zipf s=1 over 8 topics: rank-0 ≈ 2.7x uniform share
        assert!(counts[0] > 2000, "counts={counts:?}");
    }

    #[test]
    fn queries_lean_topical() {
        let c = small();
        let mut rng = Rng::new(4);
        let q = c.sample_query(&mut rng, 2, 64);
        assert_eq!(q.len(), 64);
        for &t in &q {
            assert!(t < 2048);
        }
    }

    #[test]
    fn total_tokens_positive() {
        assert!(small().total_tokens() > 10_000);
    }
}
