//! Hierarchical Navigable Small World (HNSW) approximate
//! nearest-neighbour index — the retrieval substrate the paper's RAG
//! frontend relies on (it cites Malkov & Yashunin and uses Faiss/HNSW
//! in practice; we build our own since no ANN crate exists offline).
//!
//! Standard construction: each element draws a geometric level; layer 0
//! holds all elements with `2M` links, upper layers `M` links; queries
//! greedy-descend from the top layer entry point, then run a beam
//! (`ef`) search on layer 0.

use crate::rag::embed::l2_sq;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// (distance, id) with min-order on distance for BinaryHeap<Reverse>.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Cand {
    dist: f32,
    id: u32,
}

impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        self.dist
            .partial_cmp(&other.dist)
            .unwrap()
            .then(self.id.cmp(&other.id))
    }
}

/// HNSW index over fixed-dimension f32 vectors.
pub struct Hnsw {
    vectors: Vec<Vec<f32>>,
    /// links[level][id] -> neighbour ids (only meaningful for nodes
    /// whose level >= that layer).
    links: Vec<Vec<Vec<u32>>>,
    levels: Vec<u8>,
    entry: Option<u32>,
    max_level: u8,
    m: usize,
    ef_construction: usize,
    rng: Rng,
}

impl Hnsw {
    pub fn new(m: usize, ef_construction: usize, seed: u64) -> Self {
        assert!(m >= 2);
        Hnsw {
            vectors: Vec::new(),
            links: vec![Vec::new()],
            levels: Vec::new(),
            entry: None,
            max_level: 0,
            m,
            ef_construction: ef_construction.max(m),
            rng: Rng::new(seed),
        }
    }

    pub fn len(&self) -> usize {
        self.vectors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    fn draw_level(&mut self) -> u8 {
        // geometric with p = 1/e scaled by 1/ln(M) (standard choice)
        let ml = 1.0 / (self.m as f64).ln();
        let u = self.rng.f64().max(1e-12);
        ((-u.ln() * ml).floor() as u8).min(12)
    }

    /// Insert a vector, returning its id.
    pub fn insert(&mut self, vec: Vec<f32>) -> u32 {
        let id = self.vectors.len() as u32;
        let level = self.draw_level();
        self.vectors.push(vec);
        self.levels.push(level);
        while self.links.len() <= level as usize {
            self.links.push(Vec::new());
        }
        for l in 0..self.links.len() {
            // grow adjacency tables lazily
            while self.links[l].len() < self.vectors.len() {
                self.links[l].push(Vec::new());
            }
        }
        let Some(mut ep) = self.entry else {
            self.entry = Some(id);
            self.max_level = level;
            return id;
        };

        let q = self.vectors[id as usize].clone();
        // descend through layers above the new node's level
        let mut l = self.max_level;
        while l > level {
            ep = self.greedy_closest(&q, ep, l as usize);
            if l == 0 {
                break;
            }
            l -= 1;
        }
        // insert into layers min(level, max_level)..0
        let top = level.min(self.max_level);
        for layer in (0..=top as usize).rev() {
            let found = self.search_layer(&q, ep, self.ef_construction, layer);
            let m_max = if layer == 0 { self.m * 2 } else { self.m };
            let chosen: Vec<u32> = found.iter().take(self.m).map(|c| c.id).collect();
            for &n in &chosen {
                self.links[layer][id as usize].push(n);
                self.links[layer][n as usize].push(id);
                // prune neighbour's list if over capacity
                if self.links[layer][n as usize].len() > m_max {
                    self.prune(n, layer, m_max);
                }
            }
            if let Some(best) = found.first() {
                ep = best.id;
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = Some(id);
        }
        id
    }

    fn prune(&mut self, id: u32, layer: usize, m_max: usize) {
        let base = self.vectors[id as usize].clone();
        let mut neigh: Vec<Cand> = self.links[layer][id as usize]
            .iter()
            .map(|&n| Cand {
                dist: l2_sq(&base, &self.vectors[n as usize]),
                id: n,
            })
            .collect();
        neigh.sort();
        neigh.truncate(m_max);
        self.links[layer][id as usize] = neigh.into_iter().map(|c| c.id).collect();
    }

    /// Greedy single-entry descent at one layer.
    fn greedy_closest(&self, q: &[f32], mut ep: u32, layer: usize) -> u32 {
        let mut best = l2_sq(q, &self.vectors[ep as usize]);
        loop {
            let mut improved = false;
            for &n in &self.links[layer][ep as usize] {
                let d = l2_sq(q, &self.vectors[n as usize]);
                if d < best {
                    best = d;
                    ep = n;
                    improved = true;
                }
            }
            if !improved {
                return ep;
            }
        }
    }

    /// Beam search within one layer; returns candidates sorted by
    /// distance ascending (up to `ef`).
    fn search_layer(&self, q: &[f32], ep: u32, ef: usize, layer: usize) -> Vec<Cand> {
        let mut visited = vec![false; self.vectors.len()];
        visited[ep as usize] = true;
        let d0 = l2_sq(q, &self.vectors[ep as usize]);
        // candidates: min-heap by distance (explore closest first)
        let mut cands = BinaryHeap::new();
        cands.push(std::cmp::Reverse(Cand { dist: d0, id: ep }));
        // results: max-heap (worst of the best on top)
        let mut results: BinaryHeap<Cand> = BinaryHeap::new();
        results.push(Cand { dist: d0, id: ep });
        while let Some(std::cmp::Reverse(c)) = cands.pop() {
            let worst = results.peek().map(|c| c.dist).unwrap_or(f32::INFINITY);
            if c.dist > worst && results.len() >= ef {
                break;
            }
            for &n in &self.links[layer][c.id as usize] {
                if visited[n as usize] {
                    continue;
                }
                visited[n as usize] = true;
                let d = l2_sq(q, &self.vectors[n as usize]);
                let worst = results.peek().map(|c| c.dist).unwrap_or(f32::INFINITY);
                if results.len() < ef || d < worst {
                    cands.push(std::cmp::Reverse(Cand { dist: d, id: n }));
                    results.push(Cand { dist: d, id: n });
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
        let mut out: Vec<Cand> = results.into_vec();
        out.sort();
        out
    }

    /// k-NN query: returns (id, distance) pairs, closest first.
    pub fn search(&self, q: &[f32], k: usize, ef: usize) -> Vec<(u32, f32)> {
        let Some(mut ep) = self.entry else {
            return Vec::new();
        };
        let mut l = self.max_level;
        while l > 0 {
            ep = self.greedy_closest(q, ep, l as usize);
            l -= 1;
        }
        self.search_layer(q, ep, ef.max(k), 0)
            .into_iter()
            .take(k)
            .map(|c| (c.id, c.dist))
            .collect()
    }
}

/// Brute-force exact k-NN (the correctness oracle for HNSW recall
/// tests, and a baseline for small corpora).
pub fn brute_force_knn(vectors: &[Vec<f32>], q: &[f32], k: usize) -> Vec<(u32, f32)> {
    let mut all: Vec<(u32, f32)> = vectors
        .iter()
        .enumerate()
        .map(|(i, v)| (i as u32, l2_sq(q, v)))
        .collect();
    all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_vectors(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.f32() * 2.0 - 1.0).collect())
            .collect()
    }

    #[test]
    fn exact_on_tiny_set() {
        let vs = random_vectors(10, 8, 1);
        let mut h = Hnsw::new(8, 32, 2);
        for v in &vs {
            h.insert(v.clone());
        }
        for q in &vs {
            let got = h.search(q, 1, 16);
            let want = brute_force_knn(&vs, q, 1);
            assert_eq!(got[0].0, want[0].0); // self is nearest
        }
    }

    #[test]
    fn recall_at_10_reasonable() {
        let vs = random_vectors(600, 16, 3);
        let mut h = Hnsw::new(12, 64, 4);
        for v in &vs {
            h.insert(v.clone());
        }
        let queries = random_vectors(40, 16, 5);
        let mut hits = 0;
        let mut total = 0;
        for q in &queries {
            let got: Vec<u32> = h.search(q, 10, 64).into_iter().map(|x| x.0).collect();
            let want: Vec<u32> = brute_force_knn(&vs, q, 10).into_iter().map(|x| x.0).collect();
            total += want.len();
            hits += want.iter().filter(|w| got.contains(w)).count();
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.85, "recall@10 = {recall}");
    }

    #[test]
    fn empty_index_returns_nothing() {
        let h = Hnsw::new(8, 32, 1);
        assert!(h.search(&[0.0; 8], 5, 16).is_empty());
    }

    #[test]
    fn k_larger_than_index() {
        let vs = random_vectors(3, 4, 7);
        let mut h = Hnsw::new(4, 16, 8);
        for v in &vs {
            h.insert(v.clone());
        }
        let got = h.search(&vs[0], 10, 32);
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn results_sorted_by_distance() {
        let vs = random_vectors(200, 8, 9);
        let mut h = Hnsw::new(8, 48, 10);
        for v in &vs {
            h.insert(v.clone());
        }
        let got = h.search(&vs[5], 15, 48);
        for w in got.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let vs = random_vectors(100, 8, 11);
        let build = || {
            let mut h = Hnsw::new(8, 32, 12);
            for v in &vs {
                h.insert(v.clone());
            }
            h.search(&vs[3], 5, 32)
        };
        assert_eq!(build(), build());
    }
}
