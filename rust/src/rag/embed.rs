//! Feature-hash embeddings (the MiniLM substitute).
//!
//! Documents and queries are embedded by hashing token unigrams/bigrams
//! into a fixed-dimension vector, L2-normalized. Topically-related
//! sequences (sharing a vocabulary band — see `rag::corpus`) land close
//! in cosine space, which is all retrieval quality the cache experiments
//! need: the same skewed subset of documents keeps being retrieved.

use crate::util::rng::splitmix64;

pub const EMBED_DIM: usize = 128;

/// Embed a token sequence into a unit vector.
pub fn embed(tokens: &[u32]) -> Vec<f32> {
    let mut v = vec![0.0f32; EMBED_DIM];
    if tokens.is_empty() {
        v[0] = 1.0;
        return v;
    }
    let mut add = |h: u64, w: f32| {
        let mut s = h;
        let m = splitmix64(&mut s);
        let dim = (m % EMBED_DIM as u64) as usize;
        let sign = if (m >> 63) == 0 { 1.0 } else { -1.0 };
        v[dim] += sign * w;
    };
    for (i, &t) in tokens.iter().enumerate() {
        add(t as u64 ^ 0xA5A5_5A5A, 1.0);
        if i + 1 < tokens.len() {
            let bigram = ((t as u64) << 32) | tokens[i + 1] as u64;
            add(bigram ^ 0x5A5A_A5A5_0000_0000, 0.2);
        }
    }
    normalize(&mut v);
    v
}

fn normalize(v: &mut [f32]) {
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
}

/// Cosine similarity of two unit vectors (plain dot product).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared Euclidean distance (HNSW's metric; monotone with cosine for
/// unit vectors).
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rag::corpus::{Corpus, CorpusConfig};
    use crate::util::rng::Rng;

    #[test]
    fn unit_norm() {
        let v = embed(&[1, 2, 3, 4, 5]);
        let n: f32 = v.iter().map(|x| x * x).sum();
        assert!((n - 1.0).abs() < 1e-5);
    }

    #[test]
    fn deterministic() {
        assert_eq!(embed(&[7, 8, 9]), embed(&[7, 8, 9]));
    }

    #[test]
    fn empty_sequence_ok() {
        let v = embed(&[]);
        assert!((cosine(&v, &v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn same_topic_closer_than_cross_topic() {
        let c = Corpus::generate(CorpusConfig {
            n_docs: 60,
            n_topics: 4,
            vocab: 2048,
            mean_doc_tokens: 400,
            doc_tokens_jitter: 0.1,
            seed: 5,
        });
        let mut rng = Rng::new(9);
        // average same-topic vs cross-topic similarity over many pairs
        let embs: Vec<(u32, Vec<f32>)> = c
            .docs
            .iter()
            .map(|d| (d.topic, embed(&d.tokens)))
            .collect();
        let (mut same, mut cross) = (Vec::new(), Vec::new());
        for _ in 0..2000 {
            let i = rng.below(embs.len() as u64) as usize;
            let j = rng.below(embs.len() as u64) as usize;
            if i == j {
                continue;
            }
            let s = cosine(&embs[i].1, &embs[j].1);
            if embs[i].0 == embs[j].0 {
                same.push(s);
            } else {
                cross.push(s);
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        assert!(
            mean(&same) > mean(&cross) + 0.05,
            "same={} cross={}",
            mean(&same),
            mean(&cross)
        );
    }

    #[test]
    fn l2_consistent_with_cosine_for_unit_vectors() {
        let a = embed(&[1, 2, 3]);
        let b = embed(&[4, 5, 6]);
        let l2 = l2_sq(&a, &b);
        let cos = cosine(&a, &b);
        assert!((l2 - (2.0 - 2.0 * cos)).abs() < 1e-5);
    }
}
