//! The RAG substrate: synthetic corpus, feature-hash embeddings, an
//! HNSW approximate-NN index, and the retriever that assembles
//! `[docs ‖ query]` LLM inputs (paper §2.1, Fig 2).

pub mod corpus;
pub mod embed;
pub mod hnsw;
pub mod retriever;
pub mod tokenizer;
