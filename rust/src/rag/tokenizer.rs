//! Deterministic synthetic tokenizer.
//!
//! The paper tokenizes Wikipedia/SQuAD with the served model's
//! tokenizer; our corpus is synthetic (DESIGN.md §Substitutions), so the
//! tokenizer only needs two properties: (1) deterministic text→ids, so
//! identical documents produce identical token chunks (the whole basis
//! of prefix reuse), and (2) a bounded vocabulary matching the served
//! model's embedding table.

/// Word-hash tokenizer with a fixed vocabulary size.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab_size: u32,
}

impl Tokenizer {
    pub fn new(vocab_size: u32) -> Self {
        assert!(vocab_size >= 256, "need room for byte fallbacks");
        Tokenizer { vocab_size }
    }

    /// Hash one word into [256, vocab). Ids below 256 are reserved for
    /// byte-level fallback so unknown single bytes stay distinct.
    fn word_id(&self, word: &str) -> u32 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for b in word.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01B3);
        }
        256 + (h % (self.vocab_size as u64 - 256)) as u32
    }

    /// Whitespace-split word hashing; single-char words of non-ASCII
    /// fall back to byte tokens.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for word in text.split_whitespace() {
            if word.len() == 1 && !word.is_ascii() {
                for b in word.as_bytes() {
                    out.push(*b as u32);
                }
            } else {
                out.push(self.word_id(word));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let t = Tokenizer::new(4096);
        assert_eq!(t.encode("the quick fox"), t.encode("the quick fox"));
    }

    #[test]
    fn identical_docs_identical_tokens() {
        let t = Tokenizer::new(4096);
        let doc = "retrieval augmented generation reuses kv caches";
        assert_eq!(t.encode(doc), t.encode(doc));
        // and prefix property: a prefix of words is a prefix of ids
        let full = t.encode("a b c d e");
        let pre = t.encode("a b c");
        assert_eq!(&full[..3], &pre[..]);
    }

    #[test]
    fn ids_in_vocab() {
        let t = Tokenizer::new(1000);
        for id in t.encode("some words map into range λ") {
            assert!(id < 1000);
        }
    }

    #[test]
    fn distinct_words_usually_distinct() {
        let t = Tokenizer::new(65536);
        let a = t.encode("alpha")[0];
        let b = t.encode("beta")[0];
        assert_ne!(a, b);
    }

    #[test]
    fn whitespace_normalization() {
        let t = Tokenizer::new(4096);
        assert_eq!(t.encode("a   b\n\tc"), t.encode("a b c"));
    }
}
