//! The RAG frontend: embed the query, retrieve top-k documents from the
//! HNSW index, and assemble the LLM input `[doc_a ‖ doc_b ‖ query]`.
//!
//! Retrieval latency is measured for real (the index actually runs) and
//! also modeled for the virtual-time simulator — Fig 10's point is that
//! retrieval is *much* faster than generation, which is what makes
//! queue-based prefetching possible (retrieved docs are known while the
//! request still waits).

use crate::rag::corpus::Corpus;
use crate::rag::embed::{embed, EMBED_DIM};
use crate::rag::hnsw::Hnsw;
use crate::util::rng::Rng;
use std::time::Instant;

/// Retrieval output: chosen documents + assembled token sequence.
#[derive(Clone, Debug)]
pub struct Retrieval {
    pub doc_ids: Vec<u32>,
    /// `[docs..., query]` concatenated token ids (the LLM input).
    pub tokens: Vec<u32>,
    /// Wall-clock seconds the index search actually took.
    pub search_seconds: f64,
}

/// Document retriever over a corpus.
pub struct Retriever {
    corpus: Corpus,
    index: Hnsw,
    pub top_k: usize,
    pub ef_search: usize,
}

impl Retriever {
    /// Build the index over the whole corpus (the paper's offline
    /// stage: chunk, embed, index).
    pub fn build(corpus: Corpus, top_k: usize) -> Retriever {
        let mut index = Hnsw::new(12, 96, corpus.config.seed ^ 0xABCD);
        for d in &corpus.docs {
            let v = embed(&d.tokens);
            debug_assert_eq!(v.len(), EMBED_DIM);
            index.insert(v);
        }
        Retriever {
            corpus,
            index,
            top_k,
            ef_search: 96,
        }
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    /// Online stage: embed the query, search, assemble the LLM input.
    /// Document order is by descending relevance (stable across
    /// identical queries — determinism matters for prefix reuse).
    pub fn retrieve(&self, query_tokens: &[u32]) -> Retrieval {
        let t0 = Instant::now();
        let qv = embed(query_tokens);
        let hits = self.index.search(&qv, self.top_k, self.ef_search);
        let search_seconds = t0.elapsed().as_secs_f64();
        let doc_ids: Vec<u32> = hits.iter().map(|(id, _)| *id).collect();
        let mut tokens = Vec::new();
        for id in &doc_ids {
            tokens.extend_from_slice(&self.corpus.doc(*id).tokens);
        }
        tokens.extend_from_slice(query_tokens);
        Retrieval {
            doc_ids,
            tokens,
            search_seconds,
        }
    }

    /// Generate a query for a sampled (Zipf-skewed) topic.
    pub fn sample_query(&self, rng: &mut Rng, query_tokens: usize) -> Vec<u32> {
        let topic = self.corpus.sample_topic(rng);
        self.corpus.sample_query(rng, topic, query_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rag::corpus::CorpusConfig;

    fn retriever() -> Retriever {
        let corpus = Corpus::generate(CorpusConfig {
            n_docs: 150,
            n_topics: 8,
            vocab: 2048,
            mean_doc_tokens: 120,
            doc_tokens_jitter: 0.1,
            seed: 21,
        });
        Retriever::build(corpus, 2)
    }

    #[test]
    fn retrieves_k_documents() {
        let r = retriever();
        let mut rng = Rng::new(1);
        let q = r.sample_query(&mut rng, 32);
        let out = r.retrieve(&q);
        assert_eq!(out.doc_ids.len(), 2);
        assert!(out.search_seconds >= 0.0);
    }

    #[test]
    fn deterministic_retrieval_for_identical_query() {
        // Identical queries MUST assemble identical inputs — this is
        // the precondition for any prefix reuse at all.
        let r = retriever();
        let mut rng = Rng::new(2);
        let q = r.sample_query(&mut rng, 32);
        let a = r.retrieve(&q);
        let b = r.retrieve(&q);
        assert_eq!(a.doc_ids, b.doc_ids);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn assembled_input_is_docs_then_query() {
        let r = retriever();
        let mut rng = Rng::new(3);
        let q = r.sample_query(&mut rng, 16);
        let out = r.retrieve(&q);
        let doc_len: usize = out
            .doc_ids
            .iter()
            .map(|id| r.corpus().doc(*id).tokens.len())
            .sum();
        assert_eq!(out.tokens.len(), doc_len + 16);
        assert_eq!(&out.tokens[doc_len..], &q[..]);
    }

    #[test]
    fn topical_queries_mostly_hit_same_topic_docs() {
        let r = retriever();
        let mut rng = Rng::new(4);
        let mut matches = 0;
        let mut total = 0;
        for _ in 0..40 {
            let topic = r.corpus().sample_topic(&mut rng);
            let q = r.corpus().sample_query(&mut rng, topic, 48);
            let out = r.retrieve(&q);
            // retrieved docs should mostly share a topic with each other
            if out.doc_ids.len() == 2 {
                total += 1;
                if r.corpus().doc(out.doc_ids[0]).topic == r.corpus().doc(out.doc_ids[1]).topic {
                    matches += 1;
                }
            }
        }
        assert!(matches * 2 >= total, "topical coherence too low: {matches}/{total}");
    }

    #[test]
    fn popular_topics_concentrate_on_few_documents() {
        // Zipf-skewed topics must concentrate retrievals on a small hot
        // set of documents. (Exact *input* repetition — the paper's
        // 40%/35% ratios — comes from dataset resampling in
        // serve::workload, not from emergent retrieval.)
        let r = retriever();
        let mut rng = Rng::new(5);
        let mut freq = std::collections::HashMap::new();
        let n = 200;
        for _ in 0..n {
            let q = r.sample_query(&mut rng, 32);
            for id in r.retrieve(&q).doc_ids {
                *freq.entry(id).or_insert(0u32) += 1;
            }
        }
        let mut counts: Vec<u32> = freq.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: u32 = counts.iter().sum();
        let top10: u32 = counts.iter().take(10).sum();
        // top-10 of 150 docs should absorb far more than the uniform
        // share (10/150 ≈ 6.7%)
        assert!(
            top10 as f64 / total as f64 > 0.15,
            "hot-doc concentration too low: {top10}/{total}"
        );
    }
}
