//! `pcr` — the leader binary: launch the serving simulator, the real
//! PJRT HTTP server, or individual experiments from the command line.
//!
//! Subcommands:
//!   sim      run one virtual-time serving experiment and print metrics
//!   compare  run all five systems on one workload and print a table
//!   cluster  run N replicas behind a routing policy and print per-replica + fleet metrics
//!   serve    start the real-model HTTP server (requires artifacts)
//!   corpus   generate + describe a synthetic corpus / workload
//!   version  print version/build info

use pcr::bench::Table;
use pcr::cluster;
use pcr::config::ExperimentConfig;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::serve::{engine, server};
use pcr::obs::timeline::{samples_to_csv, samples_to_json, TimelineSample};
use pcr::obs::trace::{chrome_trace, TraceEvent};
use pcr::util::cli::{Args, Cli};
use pcr::util::fmt_secs;
use pcr::util::logging::{self, Level};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            usage();
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "sim" => cmd_sim(&rest),
        "compare" => cmd_compare(&rest),
        "cluster" => cmd_cluster(&rest),
        "serve" => cmd_serve(&rest),
        "corpus" => cmd_corpus(&rest),
        "version" | "--version" => {
            println!("pcr {}", pcr::version());
            0
        }
        "--help" | "-h" | "help" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    println!(
        "pcr {} — prefetch-enhanced KV-cache reuse for RAG serving\n\n\
         USAGE: pcr <sim|compare|cluster|serve|corpus|version> [flags]\n\
         Run `pcr <cmd> --help` for per-command flags.",
        pcr::version()
    );
}

fn experiment_flags(cli: Cli) -> Cli {
    cli.opt("config", "", "config file (TOML subset); flags override it")
        .opt("model", "llama3.1-8b", "model spec name")
        .opt("platform", "a6000", "platform spec name (a6000|rtx4090)")
        .opt("rate", "0.5", "Poisson arrival rate, req/s")
        .opt("requests", "500", "number of requests")
        .opt("inputs", "250", "distinct dataset inputs")
        .opt("system", "pcr", "system variant (vllm|ccache|sccache|lmcache|pcr)")
        .opt("window", "4", "prefetch look-ahead window")
        .opt("policy", "", "eviction policy override (see cache::policy::registry; empty = system default)")
        .opt("prefetch-strategy", "", "prefetch strategy override (none|queue-window|depth-bounded[:N]; empty = system default)")
        .opt("seed", "20260710", "master seed")
        .opt("io-retries", "2", "transfer-engine retry bound for transient read errors")
        .opt("fault-seed", "64023", "fault-injection seed (decisions are pure in seed+key)")
        .opt("fault-transient", "0", "transient read-error rate per chunk [0,1]")
        .opt("fault-transient-attempts", "1", "failed attempts before a transient read succeeds")
        .opt("fault-loss", "0", "permanent chunk-loss rate [0,1]")
        .opt("fault-corrupt", "0", "at-rest corruption rate [0,1] (one-shot per chunk)")
        .opt("fault-spike", "0", "latency-spike rate per chunk load [0,1]")
        .opt("fault-spike-seconds", "0.05", "added latency per injected spike")
        .opt("fault-kill-replica", "-1", "replica to kill mid-run (cluster; -1 = none)")
        .opt("fault-kill-after", "0", "routed requests before the kill fires")
        .opt("log", "", "log level (error|warn|info|debug|trace); overrides the PCR_LOG env var")
        .opt("trace-out", "", "write the run as Chrome trace-event JSON (enables [obs] tracing; open in Perfetto)")
        .opt("timeline-out", "", "write telemetry gauges (.csv suffix = CSV, else JSON; enables [obs] timeline)")
        .switch("workload2", "sample without replacement (workload 2)")
}

/// Apply `--log <level>` (satellite of the obs PR): an explicit flag
/// beats the `PCR_LOG` environment variable.
fn apply_log_flag(args: &Args) {
    if let Some(s) = args.get("log").filter(|s| !s.is_empty()) {
        match Level::parse(s) {
            Some(l) => logging::set_level(l),
            None => {
                eprintln!("invalid --log level '{s}' (error|warn|info|debug|trace)");
                std::process::exit(2);
            }
        }
    }
}

/// Write one Chrome trace-event JSON doc (`pid` per replica).
fn write_trace(path: &str, replicas: &[(usize, &[TraceEvent])], dropped: u64) -> bool {
    let n: usize = replicas.iter().map(|(_, evs)| evs.len()).sum();
    let doc = chrome_trace(replicas);
    match std::fs::write(path, doc.dump() + "\n") {
        Ok(()) => {
            println!("trace: {n} events -> {path} ({dropped} dropped by the ring)");
            true
        }
        Err(e) => {
            eprintln!("error writing trace {path}: {e}");
            false
        }
    }
}

/// Write telemetry samples: CSV for `.csv` paths, JSON otherwise.
fn write_timeline(path: &str, samples: &[TimelineSample]) -> bool {
    let body = if path.ends_with(".csv") {
        samples_to_csv(samples)
    } else {
        samples_to_json(samples).dump() + "\n"
    };
    match std::fs::write(path, body) {
        Ok(()) => {
            println!("timeline: {} samples -> {path}", samples.len());
            true
        }
        Err(e) => {
            eprintln!("error writing timeline {path}: {e}");
            false
        }
    }
}

fn build_config(args: &Args) -> ExperimentConfig {
    apply_log_flag(args);
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.get("config").filter(|p| !p.is_empty()) {
        cfg = ExperimentConfig::from_file(path).unwrap_or_else(|e| {
            eprintln!("error loading config: {e:#}");
            std::process::exit(2);
        });
    }
    cfg.model = args.get("model").unwrap().to_string();
    cfg.platform = args.get("platform").unwrap().to_string();
    cfg.system = args.get("system").unwrap().to_string();
    cfg.rate = args.f64_of("rate");
    cfg.n_requests = args.usize_of("requests");
    cfg.n_inputs = args.usize_of("inputs");
    cfg.prefetch_window = args.usize_of("window");
    // empty = keep the config file's value (or the system default)
    let policy = args.get("policy").unwrap_or("");
    if !policy.is_empty() {
        cfg.policy = policy.to_string();
    }
    let strategy = args.get("prefetch-strategy").unwrap_or("");
    if !strategy.is_empty() {
        cfg.prefetch_strategy = strategy.to_string();
    }
    cfg.seed = args.parse_as("seed").unwrap();
    cfg.io_retries = args.parse_as("io-retries").unwrap();
    cfg.fault_seed = args.parse_as("fault-seed").unwrap();
    cfg.fault_transient = args.f64_of("fault-transient");
    cfg.fault_transient_attempts = args.parse_as("fault-transient-attempts").unwrap();
    cfg.fault_loss = args.f64_of("fault-loss");
    cfg.fault_corrupt = args.f64_of("fault-corrupt");
    cfg.fault_spike = args.f64_of("fault-spike");
    cfg.fault_spike_seconds = args.f64_of("fault-spike-seconds");
    cfg.fault_kill_replica = args.parse_as("fault-kill-replica").unwrap();
    cfg.fault_kill_after = args.parse_as("fault-kill-after").unwrap();
    cfg.oversample = !args.flag("workload2");
    // asking for an artifact implies turning the recorder on
    if args.get("trace-out").is_some_and(|p| !p.is_empty()) {
        cfg.obs_trace = true;
    }
    if args.get("timeline-out").is_some_and(|p| !p.is_empty()) {
        cfg.obs_timeline = true;
    }
    // CLI-scale corpus (full paper scale lives in the benches)
    cfg.n_docs = 1200;
    cfg.mean_doc_tokens = 1600;
    cfg.gpu_bytes = 8 << 30;
    cfg.dram_bytes = 24 << 30;
    cfg.ssd_bytes = 200 << 30;
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e:#}");
        std::process::exit(2);
    }
    cfg
}

fn cmd_sim(argv: &[String]) -> i32 {
    let cli = experiment_flags(Cli::new("pcr sim", "run one serving experiment"));
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => return cli_err(&cli, e),
    };
    let cfg = build_config(&args);
    let wl = Workload::build(&cfg);
    println!(
        "workload: {} requests over {} inputs, mean len {:.0} tokens, repetition {:.1}%",
        wl.len(),
        wl.n_distinct_inputs,
        wl.mean_input_tokens,
        wl.repetition_ratio * 100.0
    );
    let spec = match SystemSpec::from_config(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    let out = engine::run(&cfg, &spec, &wl);
    println!("system={} model={} platform={} rate={} policy={} prefetch={}",
             out.system, cfg.model, cfg.platform, cfg.rate,
             spec.policy, spec.prefetch_strategy);
    println!("{}", out.report.pretty());
    println!(
        "cache: hit-ratio {:.1}%  (gpu {} dram {} ssd {} chunks)  prefetch {}/{} (dropped {})",
        out.cache.hit_ratio() * 100.0,
        out.reused_gpu_chunks,
        out.reused_dram_chunks,
        out.reused_ssd_chunks,
        out.prefetch_completed,
        out.prefetch_submitted,
        out.prefetch_dropped
    );
    if let Some(path) = args.get("trace-out").filter(|p| !p.is_empty()) {
        if !write_trace(path, &[(0, out.trace.as_slice())], out.trace_dropped) {
            return 1;
        }
    }
    if let Some(path) = args.get("timeline-out").filter(|p| !p.is_empty()) {
        if !write_timeline(path, &out.timeline) {
            return 1;
        }
    }
    0
}

fn cmd_compare(argv: &[String]) -> i32 {
    let cli = experiment_flags(Cli::new("pcr compare", "compare all systems on one workload"));
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => return cli_err(&cli, e),
    };
    let cfg = build_config(&args);
    let wl = Workload::build(&cfg);
    let mut table = Table::new(&[
        "system", "ttft-mean", "ttft-p95", "ttft-p99", "e2el-mean",
        "hit%", "reuse%",
    ]);
    for spec in SystemSpec::all_baselines(cfg.prefetch_window) {
        let spec = spec.with_overrides(&cfg.policy, &cfg.prefetch_strategy);
        let out = engine::run(&cfg, &spec, &wl);
        table.row(&[
            out.system.to_string(),
            fmt_secs(out.report.ttft.mean),
            fmt_secs(out.report.ttft.p95),
            fmt_secs(out.report.ttft.p99),
            fmt_secs(out.report.e2el.mean),
            format!("{:.1}", out.cache.hit_ratio() * 100.0),
            format!("{:.1}", out.report.mean_reuse_ratio * 100.0),
        ]);
    }
    table.print();
    0
}

fn cmd_cluster(argv: &[String]) -> i32 {
    let cli = experiment_flags(Cli::new(
        "pcr cluster",
        "run N serving replicas behind a routing policy",
    ))
    .opt("replicas", "4", "serving replicas (1-64)")
    .opt(
        "router",
        "prefix-affinity",
        "routing policy (round-robin|least-loaded|prefix-affinity|affinity-balanced[:alpha])",
    );
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => return cli_err(&cli, e),
    };
    let mut cfg = build_config(&args);
    cfg.replicas = args.usize_of("replicas");
    cfg.router = args.get("router").unwrap().to_string();
    // build_config validated before the cluster flags landed
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e:#}");
        return 2;
    }
    let wl = Workload::build(&cfg);
    println!(
        "workload: {} requests over {} inputs, mean len {:.0} tokens, repetition {:.1}%",
        wl.len(),
        wl.n_distinct_inputs,
        wl.mean_input_tokens,
        wl.repetition_ratio * 100.0
    );
    let spec = match SystemSpec::from_config(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    let out = cluster::run(&cfg, &spec, &wl);
    println!(
        "cluster: {} replicas, router={} system={} model={} rate={}",
        out.replicas.len(),
        out.router,
        spec.name,
        cfg.model,
        cfg.rate
    );
    let mut table = Table::new(&[
        "replica", "finished", "ttft-mean", "ttft-p99", "hit%", "reuse%",
    ]);
    for (id, rep) in out.replicas.iter().enumerate() {
        table.row(&[
            id.to_string(),
            rep.report.finished.to_string(),
            fmt_secs(rep.report.ttft.mean),
            fmt_secs(rep.report.ttft.p99),
            format!("{:.1}", rep.cache.hit_ratio() * 100.0),
            format!("{:.1}", rep.report.mean_reuse_ratio * 100.0),
        ]);
    }
    table.print();
    println!("aggregate:\n{}", out.aggregate.pretty());
    println!(
        "fleet: hit-ratio {:.1}%  load-imbalance {:.3}  directory {} chunks ({} stale routings)",
        out.hit_ratio * 100.0,
        out.load_imbalance,
        out.directory_entries,
        out.directory_stale
    );
    if let Some(path) = args.get("trace-out").filter(|p| !p.is_empty()) {
        let views: Vec<(usize, &[TraceEvent])> = out
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| (i, r.trace.as_slice()))
            .collect();
        let dropped: u64 = out.replicas.iter().map(|r| r.trace_dropped).sum();
        if !write_trace(path, &views, dropped) {
            return 1;
        }
    }
    if let Some(path) = args.get("timeline-out").filter(|p| !p.is_empty()) {
        // fleet telemetry: one JSON array of samples per replica
        let per_replica: Vec<pcr::util::json::Json> = out
            .replicas
            .iter()
            .map(|r| samples_to_json(&r.timeline))
            .collect();
        let doc = pcr::util::json::Json::from_pairs(vec![("replicas", per_replica.into())]);
        match std::fs::write(path, doc.dump() + "\n") {
            Ok(()) => println!("timeline: {} replicas -> {path}", out.replicas.len()),
            Err(e) => {
                eprintln!("error writing timeline {path}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cli = Cli::new("pcr serve", "real-model HTTP server (needs `make artifacts`)")
        .opt("addr", "127.0.0.1:8180", "listen address")
        .opt("dram-chunks", "64", "DRAM tier size in chunks")
        .opt("ssd-chunks", "512", "SSD tier size in chunks")
        .opt("spill-dir", "/tmp/pcr-spill", "SSD tier directory")
        .opt("policy", "lookahead-lru", "eviction policy (see cache::policy::registry)")
        .opt("workers", "4", "HTTP worker threads")
        .opt("io-workers", "2", "transfer-engine I/O worker threads")
        .opt("io-demand-depth", "64", "transfer-engine demand queue bound")
        .opt("io-prefetch-depth", "64", "transfer-engine prefetch queue bound")
        .opt("io-retries", "2", "transfer-engine retry bound for transient read errors")
        .opt("corpus-docs", "300", "retriever corpus size (0 = no /rag route)")
        .opt("log", "", "log level (error|warn|info|debug|trace); overrides the PCR_LOG env var");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => return cli_err(&cli, e),
    };
    apply_log_flag(&args);
    let manifest = match pcr::runtime::manifest::Manifest::load(
        pcr::runtime::manifest::default_artifacts_dir(),
    ) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let dram = args.parse_as::<u64>("dram-chunks").unwrap();
    let ssd = args.parse_as::<u64>("ssd-chunks").unwrap();
    let spill = std::path::PathBuf::from(args.get("spill-dir").unwrap());
    let policy = args.get("policy").unwrap().to_string();
    let io_cfg = pcr::io::IoConfig {
        workers: args.usize_of("io-workers").max(1),
        demand_depth: args.usize_of("io-demand-depth").max(1),
        prefetch_depth: args.usize_of("io-prefetch-depth").max(1),
        retries: args.parse_as("io-retries").unwrap(),
        ..pcr::io::IoConfig::default()
    };
    let vocab = manifest.vocab as u32;
    let executor = match pcr::runtime::executor::ExecutorHandle::spawn(move || {
        pcr::runtime::executor::PjrtExecutor::with_io(
            manifest, dram, ssd, Some(&spill), &policy, io_cfg,
        )
    }) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let n_docs = args.usize_of("corpus-docs");
    let retriever = (n_docs > 0).then(|| {
        let corpus = pcr::rag::corpus::Corpus::generate(pcr::rag::corpus::CorpusConfig {
            n_docs,
            n_topics: 24,
            vocab,
            mean_doc_tokens: 360,
            doc_tokens_jitter: 0.15,
            seed: 11,
        });
        pcr::rag::retriever::Retriever::build(corpus, 2)
    });
    let state = server::ServerState {
        executor,
        retriever,
        tokenizer: pcr::rag::tokenizer::Tokenizer::new(vocab),
        ttft: std::sync::Mutex::new(Default::default()),
        requests: std::sync::Mutex::new(0),
    };
    let srv = match server::HttpServer::bind(args.get("addr").unwrap(), state) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind error: {e:#}");
            return 1;
        }
    };
    println!("pcr serving on http://{}", srv.local_addr().unwrap());
    println!("routes: POST /generate {{\"tokens\":[..]}}, POST /rag {{\"query\":\"..\"}}, GET /stats, GET /metrics (Prometheus)");
    if let Err(e) = srv.serve(args.usize_of("workers")) {
        eprintln!("server error: {e:#}");
        return 1;
    }
    0
}

fn cmd_corpus(argv: &[String]) -> i32 {
    let cli = Cli::new("pcr corpus", "generate + describe a synthetic corpus")
        .opt("docs", "2000", "number of documents")
        .opt("topics", "64", "number of topics")
        .opt("mean-tokens", "3300", "mean document length")
        .opt("seed", "7", "seed");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => return cli_err(&cli, e),
    };
    let corpus = pcr::rag::corpus::Corpus::generate(pcr::rag::corpus::CorpusConfig {
        n_docs: args.usize_of("docs"),
        n_topics: args.usize_of("topics"),
        vocab: 2048,
        mean_doc_tokens: args.usize_of("mean-tokens"),
        doc_tokens_jitter: 0.2,
        seed: args.parse_as("seed").unwrap(),
    });
    println!(
        "corpus: {} docs, {} total tokens ({:.2} GB of Llama3.1-8B KV at fp16)",
        corpus.len(),
        corpus.total_tokens(),
        corpus.total_tokens() as f64
            * pcr::hw::spec::model_spec("llama3.1-8b").unwrap().kv_bytes_per_token() as f64
            / 1e9
    );
    0
}

fn cli_err(cli: &Cli, e: pcr::util::cli::CliError) -> i32 {
    match e {
        pcr::util::cli::CliError::Help => {
            println!("{}", cli.usage());
            0
        }
        e => {
            eprintln!("error: {e}\n\n{}", cli.usage());
            2
        }
    }
}
