//! Model and platform specification tables.
//!
//! These are the calibration constants behind the analytic cost models
//! (`hw::gpu`, `hw::transfer`). Model specs are the published
//! architecture numbers for the six LLMs the paper evaluates; platform
//! specs are the paper's two testbeds (§6.1). The simulator preserves
//! *ratios* — KV bytes/token, FLOPs/byte crossovers — which is what the
//! paper's figures depend on (DESIGN.md §Substitutions).

/// Attention layout: the paper contrasts MHA (Llama2, big KV) with GQA
/// (Llama3/Qwen2.5, small KV); KV size drives most of its findings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnKind {
    Mha,
    Gqa,
}

/// Architecture constants of a served model (fp16 weights/KV on the
/// simulated testbed).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub params: u64,
    pub n_layers: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub head_dim: u32,
    pub d_model: u32,
    pub d_ff: u32,
    pub kind: AttnKind,
    /// Bytes per element for weights/KV on the simulated GPU (fp16 = 2).
    pub dtype_bytes: u32,
    /// Number of GPUs the paper runs this model on (13B/14B use 2).
    pub tensor_parallel: u32,
}

impl ModelSpec {
    /// KV-cache bytes one token occupies across all layers (K and V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.n_layers as u64
            * 2
            * self.n_kv_heads as u64
            * self.head_dim as u64
            * self.dtype_bytes as u64
    }

    /// KV bytes of one layer for `tokens` tokens (layer-wise transfer
    /// granularity).
    pub fn kv_bytes_per_layer(&self, tokens: u64) -> u64 {
        2 * self.n_kv_heads as u64
            * self.head_dim as u64
            * self.dtype_bytes as u64
            * tokens
    }

    /// Weight bytes (fp16).
    pub fn weight_bytes(&self) -> u64 {
        self.params * self.dtype_bytes as u64
    }

    /// Prefill FLOPs for computing `new` tokens given `past` tokens of
    /// context: ~2·params per token for the dense path plus the
    /// quadratic attention term 4·d·L per (query, key) pair.
    pub fn prefill_flops(&self, past: u64, new: u64) -> f64 {
        let dense = 2.0 * self.params as f64 * new as f64;
        // each new token attends to (past + its causal prefix) keys
        let avg_keys = past as f64 + (new as f64 + 1.0) / 2.0;
        let attn = 4.0
            * self.d_model as f64
            * self.n_layers as f64
            * new as f64
            * avg_keys;
        dense + attn
    }

    /// Decode FLOPs for one token at context length `ctx`.
    pub fn decode_flops(&self, ctx: u64) -> f64 {
        self.prefill_flops(ctx, 1)
    }
}

/// One of the paper's two testbeds.
#[derive(Clone, Debug)]
pub struct PlatformSpec {
    pub name: &'static str,
    pub gpus: u32,
    pub gpu_mem_bytes: u64,
    /// Dense fp16 tensor throughput per GPU.
    pub gpu_tflops: f64,
    /// Fraction of peak the prefill actually achieves (kernel efficiency).
    pub gpu_efficiency: f64,
    pub cpu_mem_bytes: u64,
    pub cpu_cores: u32,
    /// Effective PCIe bandwidth per GPU per direction (paper: ~24 GB/s
    /// measured out of 32 GB/s theoretical).
    pub pcie_gbps: f64,
    /// Per-copy-call launch overhead (the `cudaMemcpyAsync` cost the
    /// BatchAsync API amortizes — Fig 13).
    pub copy_launch_overhead_s: f64,
    pub ssd_bytes: u64,
    pub ssd_read_gbps: f64,
    pub ssd_write_gbps: f64,
}

impl PlatformSpec {
    /// Aggregate compute available to a model (tensor-parallel spreads
    /// across `tp` GPUs with a small scaling penalty).
    pub fn effective_flops(&self, tp: u32) -> f64 {
        let tp = tp.min(self.gpus) as f64;
        let scale = if tp > 1.0 { 0.9 } else { 1.0 };
        self.gpu_tflops * 1e12 * self.gpu_efficiency * tp * scale
    }

    /// GPU memory available for KV cache after weights (split across
    /// `tp` GPUs) and a fixed activation reserve.
    pub fn gpu_kv_budget(&self, model: &ModelSpec) -> u64 {
        let tp = model.tensor_parallel.min(self.gpus) as u64;
        let total = self.gpu_mem_bytes * tp;
        let reserve = (total as f64 * 0.15) as u64; // activations + fragmentation
        total.saturating_sub(model.weight_bytes()).saturating_sub(reserve)
    }
}

/// The six models from §6.1, published architecture numbers.
pub fn model_specs() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "llama2-7b", params: 6_740_000_000, n_layers: 32,
            n_heads: 32, n_kv_heads: 32, head_dim: 128, d_model: 4096,
            d_ff: 11008, kind: AttnKind::Mha, dtype_bytes: 2, tensor_parallel: 1,
        },
        ModelSpec {
            name: "llama2-13b", params: 13_000_000_000, n_layers: 40,
            n_heads: 40, n_kv_heads: 40, head_dim: 128, d_model: 5120,
            d_ff: 13824, kind: AttnKind::Mha, dtype_bytes: 2, tensor_parallel: 2,
        },
        ModelSpec {
            name: "llama3.1-8b", params: 8_030_000_000, n_layers: 32,
            n_heads: 32, n_kv_heads: 8, head_dim: 128, d_model: 4096,
            d_ff: 14336, kind: AttnKind::Gqa, dtype_bytes: 2, tensor_parallel: 1,
        },
        ModelSpec {
            name: "llama3.2-3b", params: 3_210_000_000, n_layers: 28,
            n_heads: 24, n_kv_heads: 8, head_dim: 128, d_model: 3072,
            d_ff: 8192, kind: AttnKind::Gqa, dtype_bytes: 2, tensor_parallel: 1,
        },
        ModelSpec {
            name: "qwen2.5-7b", params: 7_620_000_000, n_layers: 28,
            n_heads: 28, n_kv_heads: 4, head_dim: 128, d_model: 3584,
            d_ff: 18944, kind: AttnKind::Gqa, dtype_bytes: 2, tensor_parallel: 1,
        },
        ModelSpec {
            name: "qwen2.5-14b", params: 14_700_000_000, n_layers: 48,
            n_heads: 40, n_kv_heads: 8, head_dim: 128, d_model: 5120,
            d_ff: 13824, kind: AttnKind::Gqa, dtype_bytes: 2, tensor_parallel: 2,
        },
    ]
}

pub fn model_spec(name: &str) -> Option<ModelSpec> {
    model_specs().into_iter().find(|m| m.name == name)
}

/// The paper's two testbeds (§6.1).
pub fn platform_specs() -> Vec<PlatformSpec> {
    vec![
        PlatformSpec {
            name: "a6000",
            gpus: 2,
            gpu_mem_bytes: 48 * (1 << 30),
            gpu_tflops: 155.0,
            gpu_efficiency: 0.45,
            cpu_mem_bytes: 256 * (1 << 30),
            cpu_cores: 96,
            pcie_gbps: 24.0,
            copy_launch_overhead_s: 4.0e-6,
            ssd_bytes: 4 * (1u64 << 40),
            ssd_read_gbps: 3.0,
            ssd_write_gbps: 0.5,
        },
        PlatformSpec {
            name: "rtx4090",
            gpus: 2,
            gpu_mem_bytes: 24 * (1 << 30),
            gpu_tflops: 165.0,
            gpu_efficiency: 0.45,
            cpu_mem_bytes: 128 * (1 << 30),
            cpu_cores: 128,
            pcie_gbps: 24.0,
            copy_launch_overhead_s: 4.0e-6,
            ssd_bytes: 4 * (1u64 << 40),
            ssd_read_gbps: 3.0,
            ssd_write_gbps: 0.5,
        },
    ]
}

pub fn platform_spec(name: &str) -> Option<PlatformSpec> {
    platform_specs().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_13b_kv_matches_paper() {
        // Paper Fig 4: 8192k tokens of Llama2-13B ≈ 6.23 TB.
        let m = model_spec("llama2-13b").unwrap();
        let total = m.kv_bytes_per_token() * 8_192_000;
        let tb = total as f64 / 1e12;
        assert!((tb - 6.7).abs() < 0.6, "got {tb} TB"); // 819200 B/token
        assert_eq!(m.kv_bytes_per_token(), 819_200);
    }

    #[test]
    fn gqa_kv_smaller_than_mha() {
        let l2 = model_spec("llama2-7b").unwrap();
        let q = model_spec("qwen2.5-7b").unwrap();
        assert!(l2.kv_bytes_per_token() > 4 * q.kv_bytes_per_token());
        assert_eq!(l2.kind, AttnKind::Mha);
        assert_eq!(q.kind, AttnKind::Gqa);
    }

    #[test]
    fn h100_esque_token_capacity_sanity() {
        // §3: 80 GB holds ~163k tokens of Llama2-7B KV.
        let m = model_spec("llama2-7b").unwrap();
        let tokens = 80e9 / m.kv_bytes_per_token() as f64;
        assert!((tokens - 152_000.0).abs() < 25_000.0, "tokens={tokens}");
    }

    #[test]
    fn prefill_flops_superlinear() {
        // Fig 4's point: TTFT grows super-linearly with input length.
        let m = model_spec("qwen2.5-14b").unwrap();
        let f1 = m.prefill_flops(0, 4096);
        let f2 = m.prefill_flops(0, 8192);
        assert!(f2 > 2.0 * f1);
        assert!(f2 < 4.0 * f1);
    }

    #[test]
    fn kv_budget_positive_for_all_pairs() {
        for p in platform_specs() {
            for m in model_specs() {
                let b = p.gpu_kv_budget(&m);
                assert!(b > 0, "{} on {} has no KV budget", m.name, p.name);
            }
        }
    }

    #[test]
    fn layer_bytes_times_layers_is_total() {
        for m in model_specs() {
            assert_eq!(
                m.kv_bytes_per_layer(1) * m.n_layers as u64,
                m.kv_bytes_per_token()
            );
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(model_spec("llama3.2-3b").is_some());
        assert!(model_spec("nope").is_none());
        assert!(platform_spec("rtx4090").is_some());
    }
}
