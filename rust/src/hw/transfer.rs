//! Data-movement cost models: PCIe (CPU↔GPU) and SSD (DRAM↔disk)
//! channels, block-by-block vs batched copy launch overhead (Fig 13),
//! and the synchronous-reuse overhead formula of Eq. (1).
//!
//! A `Channel` is a FIFO bandwidth resource with a virtual-time cursor,
//! so the same object serves both analytic formulas and the serving
//! simulator's asynchronous transfer bookkeeping.

use crate::hw::spec::{ModelSpec, PlatformSpec};

/// A directional bandwidth channel with per-call launch overhead and a
/// FIFO availability cursor in virtual time.
#[derive(Clone, Debug)]
pub struct Channel {
    pub name: &'static str,
    pub bytes_per_s: f64,
    pub launch_overhead_s: f64,
    /// Virtual time at which the channel becomes free.
    pub free_at: f64,
    /// Total bytes moved (for utilization reporting).
    pub bytes_moved: u64,
}

impl Channel {
    pub fn new(name: &'static str, gbps: f64, launch_overhead_s: f64) -> Self {
        Channel {
            name,
            bytes_per_s: gbps * 1e9,
            launch_overhead_s,
            free_at: 0.0,
            bytes_moved: 0,
        }
    }

    /// Pure cost of one copy call moving `bytes` (no queueing).
    pub fn copy_time(&self, bytes: u64) -> f64 {
        self.launch_overhead_s + bytes as f64 / self.bytes_per_s
    }

    /// Cost of moving `bytes` split into `calls` separate copy calls
    /// (block-by-block) vs one batched call — the Fig 13 contrast.
    pub fn copy_time_calls(&self, bytes: u64, calls: u64) -> f64 {
        self.launch_overhead_s * calls as f64 + bytes as f64 / self.bytes_per_s
    }

    /// Enqueue a transfer at `now`; returns (start, finish) and advances
    /// the cursor. FIFO: starts when both `now` and prior work allow.
    pub fn enqueue(&mut self, now: f64, bytes: u64) -> (f64, f64) {
        let start = now.max(self.free_at);
        let finish = start + self.copy_time(bytes);
        self.free_at = finish;
        self.bytes_moved += bytes;
        (start, finish)
    }

    /// Time already committed beyond `now` (queue depth in seconds).
    pub fn backlog(&self, now: f64) -> f64 {
        (self.free_at - now).max(0.0)
    }

    pub fn reset(&mut self) {
        self.free_at = 0.0;
        self.bytes_moved = 0;
    }
}

/// The full transfer fabric of one platform.
#[derive(Clone, Debug)]
pub struct TransferFabric {
    pub h2d: Channel,
    pub d2h: Channel,
    pub ssd_read: Channel,
    pub ssd_write: Channel,
}

impl TransferFabric {
    pub fn new(p: &PlatformSpec) -> Self {
        TransferFabric {
            h2d: Channel::new("pcie-h2d", p.pcie_gbps, p.copy_launch_overhead_s),
            d2h: Channel::new("pcie-d2h", p.pcie_gbps, p.copy_launch_overhead_s),
            // SSD ops go through the block layer; launch overhead is
            // a syscall + NVMe queue doorbell, ~10µs.
            ssd_read: Channel::new("ssd-read", p.ssd_read_gbps, 10e-6),
            ssd_write: Channel::new("ssd-write", p.ssd_write_gbps, 10e-6),
        }
    }

    pub fn reset(&mut self) {
        self.h2d.reset();
        self.d2h.reset();
        self.ssd_read.reset();
        self.ssd_write.reset();
    }
}

/// Copy strategies for moving one KV chunk into paged GPU blocks
/// (Fig 13: block-by-block `cudaMemcpyAsync` vs `cudaMemcpyBatchAsync`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyMode {
    BlockByBlock,
    BatchAsync,
}

/// Time to copy one chunk of `chunk_tokens` tokens of ONE layer's KV,
/// scattered into `chunk_tokens / block_tokens` non-contiguous GPU
/// blocks (vLLM paging).
pub fn chunk_copy_time(
    ch: &Channel,
    model: &ModelSpec,
    chunk_tokens: u64,
    block_tokens: u64,
    mode: CopyMode,
) -> f64 {
    let bytes = model.kv_bytes_per_layer(chunk_tokens);
    // K and V are separate regions per block: 2 copies per block.
    let blocks = 2 * chunk_tokens.div_ceil(block_tokens);
    match mode {
        CopyMode::BlockByBlock => ch.copy_time_calls(bytes, blocks),
        CopyMode::BatchAsync => ch.copy_time_calls(bytes, 1),
    }
}

/// Eq. (1): total processing time of a request with `n1` reused tokens
/// and `n2 = n - n1` computed tokens under *synchronous* transfers,
/// where `c1` = full-sequence transfer time and `c2` = full-sequence
/// compute time. The paper's point: the transfer overhead contributes
/// a constant `c1` regardless of the reuse ratio.
pub fn eq1_sync_total(n1: u64, n2: u64, c1: f64, c2: f64) -> f64 {
    let n = (n1 + n2) as f64;
    if n == 0.0 {
        return 0.0;
    }
    // load reused KV + compute the rest + offload newly generated KV
    (n1 as f64 / n) * c1 + (n2 as f64 / n) * c2 + (n2 as f64 / n) * c1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::spec::{model_spec, platform_spec};

    #[test]
    fn eq1_is_constant_plus_compute_share() {
        // C = C1 + (N2/N)·C2 — check the algebraic identity.
        let (c1, c2) = (0.5, 2.0);
        for n1 in [0u64, 1000, 4096, 8192] {
            let n2 = 8192 - n1;
            let total = eq1_sync_total(n1, n2, c1, c2);
            let expect = c1 + (n2 as f64 / 8192.0) * c2;
            assert!((total - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn paper_fig13_batch_vs_blockwise_shape() {
        // Llama2-13B, one layer of a 256-token chunk, 16-token vLLM
        // blocks, 32 GB/s PCIe: paper measures 0.671 ms block-by-block
        // vs 0.261 ms batched. With published arch numbers the copy is
        // bandwidth-dominated; what must reproduce is the ~2.5x gap
        // direction and the sub-millisecond magnitudes.
        let m = model_spec("llama2-13b").unwrap();
        let ch = Channel::new("pcie", 32.0, 12e-6); // jetty: per-call cost incl. driver
        let slow = chunk_copy_time(&ch, &m, 256, 16, CopyMode::BlockByBlock);
        let fast = chunk_copy_time(&ch, &m, 256, 16, CopyMode::BatchAsync);
        assert!(slow > 1.8 * fast, "slow={slow} fast={fast}");
        assert!(slow < 2e-3 && fast < 1e-3);
    }

    #[test]
    fn channel_fifo_queueing() {
        let mut ch = Channel::new("t", 1.0, 0.0); // 1 GB/s
        let (s1, f1) = ch.enqueue(0.0, 1_000_000_000); // 1s
        let (s2, f2) = ch.enqueue(0.5, 500_000_000); // queued behind
        assert_eq!((s1, f1), (0.0, 1.0));
        assert_eq!(s2, 1.0);
        assert!((f2 - 1.5).abs() < 1e-12);
        assert!((ch.backlog(1.2) - 0.3).abs() < 1e-9);
        assert_eq!(ch.bytes_moved, 1_500_000_000);
    }

    #[test]
    fn enqueue_after_idle_starts_at_now() {
        let mut ch = Channel::new("t", 1.0, 0.0);
        ch.enqueue(0.0, 1_000_000_000);
        let (s, _) = ch.enqueue(5.0, 1);
        assert_eq!(s, 5.0);
    }

    #[test]
    fn ssd_write_slower_than_read() {
        let p = platform_spec("a6000").unwrap();
        let f = TransferFabric::new(&p);
        let bytes = 1 << 30;
        assert!(f.ssd_write.copy_time(bytes) > 5.0 * f.ssd_read.copy_time(bytes));
    }

    #[test]
    fn batched_copy_never_slower() {
        let m = model_spec("qwen2.5-7b").unwrap();
        let p = platform_spec("rtx4090").unwrap();
        let ch = Channel::new("pcie", p.pcie_gbps, p.copy_launch_overhead_s);
        for chunk in [64u64, 256, 1024] {
            for block in [8u64, 16, 32] {
                let a = chunk_copy_time(&ch, &m, chunk, block, CopyMode::BlockByBlock);
                let b = chunk_copy_time(&ch, &m, chunk, block, CopyMode::BatchAsync);
                assert!(b <= a);
            }
        }
    }
}
