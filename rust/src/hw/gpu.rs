//! Analytic GPU compute-cost model.
//!
//! The paper's testbed GPUs are simulated (DESIGN.md §Substitutions): we
//! translate prefill/decode work into FLOPs via the model architecture
//! (`hw::spec::ModelSpec`) and divide by the platform's effective
//! throughput. This preserves the two properties the paper's evaluation
//! rests on: TTFT grows super-linearly with input length (Fig 4), and
//! compute time dominates PCIe/SSD transfer time at matching token
//! counts (Fig 5) — so KV reuse beats recomputation.

use crate::hw::spec::{ModelSpec, PlatformSpec};

/// Compute-time oracle for one (model, platform) pair.
#[derive(Clone, Debug)]
pub struct GpuCostModel {
    /// Effective FLOP/s available to this model on this platform.
    pub flops: f64,
    /// Fixed per-forward-pass launch/framework overhead.
    pub step_overhead_s: f64,
    /// HBM bandwidth bound for decode (memory-bound regime), bytes/s.
    pub hbm_bytes_per_s: f64,
    model: ModelSpec,
}

impl GpuCostModel {
    pub fn new(model: &ModelSpec, platform: &PlatformSpec) -> Self {
        GpuCostModel {
            flops: platform.effective_flops(model.tensor_parallel),
            step_overhead_s: 2.0e-3,
            // decode streams weights + KV; approximate HBM bw by scaling
            // compute ratio (A6000 768 GB/s, 4090 1008 GB/s ~ 1 TB/s)
            hbm_bytes_per_s: 0.85e12 * model.tensor_parallel.min(platform.gpus) as f64,
            model: model.clone(),
        }
    }

    /// Prefill time for `new` computed tokens on top of `past` reused
    /// context tokens (one forward pass, compute-bound).
    pub fn prefill_time(&self, past: u64, new: u64) -> f64 {
        if new == 0 {
            return 0.0;
        }
        self.step_overhead_s + self.model.prefill_flops(past, new) / self.flops
    }

    /// Per-layer prefill time (layer-wise overlap granularity). The
    /// forward pass is uniform across layers to first order.
    pub fn prefill_time_per_layer(&self, past: u64, new: u64) -> f64 {
        self.prefill_time(past, new) / self.model.n_layers as f64
    }

    /// One decode step at context length `ctx`: max of compute-bound and
    /// memory-bound (weights streaming) costs.
    pub fn decode_time(&self, ctx: u64) -> f64 {
        let compute = self.model.decode_flops(ctx) / self.flops;
        let memory = (self.model.weight_bytes() as f64
            + self.model.kv_bytes_per_token() as f64 * ctx as f64)
            / self.hbm_bytes_per_s;
        self.step_overhead_s * 0.5 + compute.max(memory)
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::spec::{model_spec, platform_spec};

    fn cm(model: &str, plat: &str) -> GpuCostModel {
        GpuCostModel::new(&model_spec(model).unwrap(), &platform_spec(plat).unwrap())
    }

    #[test]
    fn ttft_superlinear_in_input_length() {
        let g = cm("qwen2.5-14b", "a6000");
        let t4k = g.prefill_time(0, 4096);
        let t8k = g.prefill_time(0, 8192);
        assert!(t8k > 2.0 * t4k, "t4k={t4k} t8k={t8k}");
    }

    #[test]
    fn reuse_reduces_prefill_time() {
        let g = cm("llama2-13b", "a6000");
        let full = g.prefill_time(0, 8192);
        let half = g.prefill_time(4096, 4096);
        assert!(half < 0.75 * full);
    }

    #[test]
    fn paper_scale_8k_prefill_seconds() {
        // Fig 5: Llama2-13B at 8k tokens computes in ~2s on the paper's
        // testbed; our calibration should land in the same ballpark.
        let g = cm("llama2-13b", "a6000");
        let t = g.prefill_time(0, 8192);
        assert!((0.5..6.0).contains(&t), "t={t}");
    }

    #[test]
    fn compute_exceeds_pcie_load_at_same_tokens() {
        // Fig 5's key crossover: loading KV from CPU memory is faster
        // than recomputing those tokens, for every model.
        for m in crate::hw::spec::model_specs() {
            let p = platform_spec("a6000").unwrap();
            let g = GpuCostModel::new(&m, &p);
            for tokens in [1024u64, 4096, 8192] {
                let compute = g.prefill_time(0, tokens);
                let load = (m.kv_bytes_per_token() * tokens) as f64 / (p.pcie_gbps * 1e9);
                assert!(
                    load < compute,
                    "{}: load {load} !< compute {compute} at {tokens}",
                    m.name
                );
            }
        }
    }

    #[test]
    fn decode_is_memory_bound_and_cheap() {
        let g = cm("llama2-7b", "a6000");
        let d = g.decode_time(4096);
        assert!(d < g.prefill_time(0, 4096));
        assert!(d > 0.0);
    }

    #[test]
    fn per_layer_time_sums_to_total() {
        let g = cm("llama3.1-8b", "rtx4090");
        let total = g.prefill_time(1024, 2048);
        let per = g.prefill_time_per_layer(1024, 2048);
        assert!((per * 32.0 - total).abs() < 1e-9);
    }
}
