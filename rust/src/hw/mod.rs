//! Hardware models: the simulated testbeds (DESIGN.md §Substitutions).
//!
//! * [`spec`] — published architecture/platform constants (six models,
//!   two testbeds) behind every ratio the paper's figures depend on.
//! * [`gpu`] — analytic prefill/decode compute-cost model.
//! * [`transfer`] — PCIe/SSD bandwidth channels, batched-copy modeling
//!   (Fig 13) and the Eq. (1) synchronous-overhead formula.

pub mod gpu;
pub mod spec;
pub mod transfer;
