//! TOML-subset config file parser (no `toml`/`serde` offline).
//!
//! Supported grammar — everything the experiment configs need:
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! num = 1.5
//! flag = true
//! list = [1, 2, 3]
//! ```
//!
//! Values land in a flat `section.key -> Value` map.

use std::collections::BTreeMap;

/// A parsed scalar or list value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Flat config map: keys are `section.key` (or bare `key` before any
/// section header).
pub type ConfigMap = BTreeMap<String, Value>;

/// Parse config text.
pub fn parse(text: &str) -> Result<ConfigMap, ParseError> {
    let mut out = ConfigMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name.strip_suffix(']').ok_or(ParseError {
                line: line_no,
                msg: "unterminated section header".into(),
            })?;
            section = name.trim().to_string();
            continue;
        }
        let (key, val) = line.split_once('=').ok_or(ParseError {
            line: line_no,
            msg: "expected key = value".into(),
        })?;
        let key = key.trim();
        if key.is_empty() {
            return Err(ParseError {
                line: line_no,
                msg: "empty key".into(),
            });
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(val.trim()).map_err(|msg| ParseError {
            line: line_no,
            msg,
        })?;
        out.insert(full_key, value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: # outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated list".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::List(Vec::new()));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::List(items));
    }
    // numbers, with unit suffixes for byte sizes: 4GiB, 256MiB, 2TiB
    for (suffix, mult) in [
        ("TiB", (1u64 << 40) as f64),
        ("GiB", (1u64 << 30) as f64),
        ("MiB", (1u64 << 20) as f64),
        ("KiB", 1024.0),
    ] {
        if let Some(num) = s.strip_suffix(suffix) {
            let x: f64 = num
                .trim()
                .parse()
                .map_err(|_| format!("bad number '{num}'"))?;
            return Ok(Value::Num(x * mult));
        }
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("unrecognized value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
# experiment
top = 1
[workload]
rate = 0.75          # req/s
name = "workload1"
oversample = true
rates = [0.5, 0.75, 1.0]
[cache]
dram = 256GiB
ssd = 2TiB
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["top"], Value::Num(1.0));
        assert_eq!(m["workload.rate"], Value::Num(0.75));
        assert_eq!(m["workload.name"].as_str(), Some("workload1"));
        assert_eq!(m["workload.oversample"].as_bool(), Some(true));
        assert_eq!(m["workload.rates"].as_list().unwrap().len(), 3);
        assert_eq!(m["cache.dram"].as_u64(), Some(256 << 30));
        assert_eq!(m["cache.ssd"].as_u64(), Some(2u64 << 40));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[open").is_err());
        assert!(parse("just a line").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("k = wat").is_err());
    }

    #[test]
    fn comments_inside_strings_preserved() {
        let m = parse("k = \"a#b\"").unwrap();
        assert_eq!(m["k"].as_str(), Some("a#b"));
    }

    #[test]
    fn empty_list() {
        let m = parse("k = []").unwrap();
        assert_eq!(m["k"].as_list().unwrap().len(), 0);
    }
}
