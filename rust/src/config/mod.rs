//! Experiment configuration: one struct that pins down everything a
//! serving run needs — model, platform, cache geometry, policies,
//! workload shape, arrival process — loadable from a TOML-subset file
//! ([`file`]) and overridable from the CLI. Every experiment in
//! `rust/benches/` is a set of `ExperimentConfig` values, so paper
//! figures replay from config alone.

pub mod file;

use crate::config::file::{ConfigMap, Value};
use anyhow::{bail, Context, Result};

/// Full configuration of one serving experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // --- what is served, on what ---
    /// Model spec name (see `hw::spec::model_specs`).
    pub model: String,
    /// Platform spec name (`a6000` | `rtx4090`).
    pub platform: String,
    /// System variant: `vllm` | `ccache` | `sccache` | `lmcache` | `pcr`.
    pub system: String,

    // --- cache engine ---
    /// Cache chunk granularity in tokens (paper: 256).
    pub chunk_tokens: usize,
    /// GPU KV capacity in bytes (0 = platform budget after weights).
    /// Overriding below the platform budget emulates co-located memory
    /// pressure and is how tests exercise the full tier hierarchy.
    pub gpu_bytes: u64,
    /// DRAM KV capacity in bytes (0 = platform CPU memory budget).
    pub dram_bytes: u64,
    /// SSD KV capacity in bytes (0 = platform SSD budget).
    pub ssd_bytes: u64,
    /// Eviction policy name, resolved through
    /// `cache::policy::registry` (case-insensitive). Empty = use the
    /// system variant's default (e.g. `pcr` runs look-ahead LRU, the
    /// baselines run LRU).
    pub policy: String,
    /// Look-ahead LRU horizon: queued requests examined for protection.
    pub lookahead_window: usize,
    /// Queue-based prefetch window (paper: 4; Fig 18 sweeps it).
    pub prefetch_window: usize,
    /// Prefetch strategy name, resolved through
    /// `cache::prefetch::registry` (case-insensitive;
    /// `depth-bounded:<n>` is accepted). Empty = the system variant's
    /// default (`queue-window` for prefetching systems, else `none`).
    pub prefetch_strategy: String,
    /// Layer-wise overlap mode: `sync` | `only-up` | `only-down` | `up-down`.
    pub overlap: String,
    /// Use batched chunk copies (`cudaMemcpyBatchAsync` analogue).
    pub batch_async: bool,
    /// Select eviction victims through the incremental per-tier rank
    /// index (amortized O(log n); §Perf iteration 3) instead of the
    /// fused O(n) scan. On by default; the off position is the A/B
    /// baseline for benches and parity tests.
    pub indexed_eviction: bool,

    // --- transfer engine (`[io]` section) ---
    /// Dedicated I/O worker threads for the real-path transfer engine.
    pub io_workers: usize,
    /// Bound on queued demand tickets (backpressure beyond it).
    pub io_demand_depth: usize,
    /// Bound on in-flight prefetch loads (both the real engine's queue
    /// and the simulator's in-flight window).
    pub io_prefetch_depth: usize,
    /// Times a failed SSD read is retried before degrading to
    /// recompute (real path and virtual fault model share this bound).
    pub io_retries: u32,
    /// Base backoff between real-path retry attempts, doubled per
    /// attempt (milliseconds).
    pub io_retry_backoff_ms: u64,

    // --- fault injection (`[faults]` section; all off by default) ---
    /// Seed for every per-key fault decision.
    pub fault_seed: u64,
    /// Probability a chunk key's reads fail transiently.
    pub fault_transient: f64,
    /// Consecutive failing attempts for a transient-flaky key.
    pub fault_transient_attempts: u32,
    /// Probability a chunk key's stored bytes are permanently lost.
    pub fault_loss: f64,
    /// Probability a chunk key's first stored copy is corrupted.
    pub fault_corrupt: f64,
    /// Probability a read takes a latency spike.
    pub fault_spike: f64,
    /// Extra latency per spike, seconds.
    pub fault_spike_seconds: f64,
    /// Cluster: replica index to kill mid-run (-1 = nobody dies).
    pub fault_kill_replica: i64,
    /// Cluster: the kill fires once this many requests were routed.
    pub fault_kill_after: u64,

    // --- observability (`[obs]` section; all off by default) ---
    /// Record per-request spans and cache/io/cluster events into the
    /// trace ring (exportable as Chrome trace JSON via `--trace-out`).
    pub obs_trace: bool,
    /// Trace ring capacity in events; the oldest are dropped (and
    /// counted) beyond it.
    pub obs_trace_capacity: usize,
    /// Sample periodic telemetry gauges (tier occupancy, queue depth,
    /// inflight prefetches, windowed hit ratio).
    pub obs_timeline: bool,
    /// Gauge sampling interval, virtual seconds.
    pub obs_timeline_interval: f64,
    /// Flight-recorder depth: events snapshotted when a degrade or
    /// failover fires (0 disables; needs `obs.trace` for a feed).
    pub obs_flight_depth: usize,

    // --- cluster serving (`[cluster]` section) ---
    /// Serving replicas driven by `cluster::sim` (1 = the single-engine
    /// path). Bounded by the directory's replica-set word width (64).
    pub replicas: usize,
    /// Routing policy name, resolved through
    /// `cluster::router::registry` (case-insensitive;
    /// `affinity-balanced:<alpha>` is accepted).
    pub router: String,

    // --- workload (paper §6.1) ---
    /// Distinct inputs in the dataset (paper: 1000 / 2000).
    pub n_inputs: usize,
    /// Sample requests with replacement (workload 1) or shuffle-cycle
    /// without (workload 2).
    pub oversample: bool,
    /// Total requests issued (paper: 2000 sampling iterations).
    pub n_requests: usize,
    /// Poisson arrival rate, requests/second.
    pub rate: f64,
    /// Documents retrieved per query (paper: 2).
    pub docs_per_query: usize,
    /// Query length in tokens.
    pub query_tokens: usize,
    /// Output tokens per request (paper: 16, prefill-focused).
    pub output_tokens: usize,

    // --- corpus ---
    pub n_docs: usize,
    pub n_topics: usize,
    /// Mean document length in tokens (2 docs + query ≈ 6.8k as in the
    /// paper).
    pub mean_doc_tokens: usize,

    /// Master seed (forked per component).
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "llama3.1-8b".into(),
            platform: "a6000".into(),
            system: "pcr".into(),
            chunk_tokens: 256,
            gpu_bytes: 0,
            dram_bytes: 0,
            ssd_bytes: 0,
            policy: String::new(),
            lookahead_window: 4,
            prefetch_window: 4,
            prefetch_strategy: String::new(),
            overlap: "up-down".into(),
            batch_async: true,
            indexed_eviction: true,
            io_workers: 2,
            io_demand_depth: 64,
            io_prefetch_depth: 64,
            io_retries: 2,
            io_retry_backoff_ms: 1,
            fault_seed: 0xFA17,
            fault_transient: 0.0,
            fault_transient_attempts: 1,
            fault_loss: 0.0,
            fault_corrupt: 0.0,
            fault_spike: 0.0,
            fault_spike_seconds: 0.05,
            fault_kill_replica: -1,
            fault_kill_after: 0,
            obs_trace: false,
            obs_trace_capacity: 65536,
            obs_timeline: false,
            obs_timeline_interval: 0.5,
            obs_flight_depth: 64,
            replicas: 1,
            router: "prefix-affinity".into(),
            n_inputs: 1000,
            oversample: true,
            n_requests: 2000,
            rate: 0.5,
            docs_per_query: 2,
            query_tokens: 64,
            output_tokens: 16,
            n_docs: 4000,
            n_topics: 128,
            mean_doc_tokens: 3368, // 2*3368 + 64 ≈ 6.8k tokens
            seed: 20260710,
        }
    }
}

impl ExperimentConfig {
    /// Apply overrides from a parsed config map (`section.key` keys —
    /// see module docs of [`file`] for the accepted sections).
    pub fn apply(&mut self, map: &ConfigMap) -> Result<()> {
        for (key, val) in map {
            self.apply_one(key, val)
                .with_context(|| format!("config key '{key}'"))?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, val: &Value) -> Result<()> {
        let need_str = || -> Result<String> {
            val.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow::anyhow!("expected string"))
        };
        let need_f64 = || -> Result<f64> {
            val.as_f64().ok_or_else(|| anyhow::anyhow!("expected number"))
        };
        let need_bool = || -> Result<bool> {
            val.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"))
        };
        match key {
            "serve.model" | "model" => self.model = need_str()?,
            "serve.platform" | "platform" => self.platform = need_str()?,
            "serve.system" | "system" => self.system = need_str()?,
            "cache.chunk_tokens" => self.chunk_tokens = need_f64()? as usize,
            "cache.gpu_bytes" => self.gpu_bytes = need_f64()? as u64,
            "cache.dram_bytes" => self.dram_bytes = need_f64()? as u64,
            "cache.ssd_bytes" => self.ssd_bytes = need_f64()? as u64,
            "cache.policy" => self.policy = need_str()?,
            "cache.lookahead_window" => self.lookahead_window = need_f64()? as usize,
            "cache.prefetch_window" | "prefetch.window" => {
                self.prefetch_window = need_f64()? as usize
            }
            "prefetch.strategy" => self.prefetch_strategy = need_str()?,
            "cache.overlap" => self.overlap = need_str()?,
            "cache.batch_async" => self.batch_async = need_bool()?,
            "cache.indexed_eviction" => self.indexed_eviction = need_bool()?,
            "io.workers" => self.io_workers = need_f64()? as usize,
            "io.demand_depth" => self.io_demand_depth = need_f64()? as usize,
            "io.prefetch_depth" => self.io_prefetch_depth = need_f64()? as usize,
            "io.retries" => self.io_retries = need_f64()? as u32,
            "io.retry_backoff_ms" => self.io_retry_backoff_ms = need_f64()? as u64,
            "faults.seed" => self.fault_seed = need_f64()? as u64,
            "faults.transient" => self.fault_transient = need_f64()?,
            "faults.transient_attempts" => {
                self.fault_transient_attempts = need_f64()? as u32
            }
            "faults.loss" => self.fault_loss = need_f64()?,
            "faults.corrupt" => self.fault_corrupt = need_f64()?,
            "faults.spike" => self.fault_spike = need_f64()?,
            "faults.spike_seconds" => self.fault_spike_seconds = need_f64()?,
            "faults.kill_replica" => self.fault_kill_replica = need_f64()? as i64,
            "faults.kill_after" => self.fault_kill_after = need_f64()? as u64,
            "obs.trace" => self.obs_trace = need_bool()?,
            "obs.trace_capacity" => self.obs_trace_capacity = need_f64()? as usize,
            "obs.timeline" => self.obs_timeline = need_bool()?,
            "obs.timeline_interval" => self.obs_timeline_interval = need_f64()?,
            "obs.flight_depth" => self.obs_flight_depth = need_f64()? as usize,
            "cluster.replicas" => self.replicas = need_f64()? as usize,
            "cluster.router" => self.router = need_str()?,
            "workload.n_inputs" => self.n_inputs = need_f64()? as usize,
            "workload.oversample" => self.oversample = need_bool()?,
            "workload.n_requests" => self.n_requests = need_f64()? as usize,
            "workload.rate" => self.rate = need_f64()?,
            "workload.docs_per_query" => self.docs_per_query = need_f64()? as usize,
            "workload.query_tokens" => self.query_tokens = need_f64()? as usize,
            "workload.output_tokens" => self.output_tokens = need_f64()? as usize,
            "corpus.n_docs" => self.n_docs = need_f64()? as usize,
            "corpus.n_topics" => self.n_topics = need_f64()? as usize,
            "corpus.mean_doc_tokens" => self.mean_doc_tokens = need_f64()? as usize,
            "seed" => self.seed = need_f64()? as u64,
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// Load defaults + file overrides.
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let map = file::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        use crate::cache::{policy, prefetch};
        use crate::cluster::directory as cluster_directory;
        use crate::cluster::router::registry as router_registry;
        use crate::hw::spec::{model_spec, platform_spec};
        use crate::serve::system::SystemSpec;
        use crate::sim::pipeline::OverlapMode;
        if model_spec(&self.model).is_none() {
            bail!("unknown model '{}'", self.model);
        }
        if platform_spec(&self.platform).is_none() {
            bail!("unknown platform '{}'", self.platform);
        }
        if !self.policy.is_empty() && policy::registry::parse(&self.policy).is_none() {
            bail!(
                "unknown policy '{}' (registered: {})",
                self.policy,
                policy::registry::names_joined()
            );
        }
        if !self.prefetch_strategy.is_empty()
            && prefetch::registry::parse(&self.prefetch_strategy).is_none()
        {
            bail!(
                "unknown prefetch strategy '{}' (registered: {})",
                self.prefetch_strategy,
                prefetch::registry::names_joined()
            );
        }
        if OverlapMode::parse(&self.overlap).is_none() {
            bail!("unknown overlap mode '{}'", self.overlap);
        }
        if !SystemSpec::NAMES.contains(&self.system.as_str()) {
            bail!(
                "unknown system '{}' (registered: {})",
                self.system,
                SystemSpec::names_joined()
            );
        }
        if self.chunk_tokens == 0 || self.rate <= 0.0 || self.n_requests == 0 {
            bail!("degenerate workload parameters");
        }
        if self.io_workers == 0 || self.io_demand_depth == 0 || self.io_prefetch_depth == 0 {
            bail!("io.workers / io.demand_depth / io.prefetch_depth must be >= 1");
        }
        if self.replicas == 0 || self.replicas > cluster_directory::MAX_REPLICAS {
            bail!(
                "cluster.replicas must be in 1..={} (got {})",
                cluster_directory::MAX_REPLICAS,
                self.replicas
            );
        }
        if router_registry::parse(&self.router).is_none() {
            bail!(
                "unknown router '{}' (registered: {})",
                self.router,
                router_registry::names_joined()
            );
        }
        for (name, rate) in [
            ("faults.transient", self.fault_transient),
            ("faults.loss", self.fault_loss),
            ("faults.corrupt", self.fault_corrupt),
            ("faults.spike", self.fault_spike),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                bail!("{name} must be a probability in [0, 1] (got {rate})");
            }
        }
        if self.fault_spike_seconds < 0.0 {
            bail!("faults.spike_seconds must be >= 0");
        }
        if self.obs_trace && self.obs_trace_capacity == 0 {
            bail!("obs.trace_capacity must be >= 1 when obs.trace is on");
        }
        if self.obs_timeline && self.obs_timeline_interval <= 0.0 {
            bail!(
                "obs.timeline_interval must be > 0 (got {})",
                self.obs_timeline_interval
            );
        }
        if self.fault_kill_replica >= 0
            && self.fault_kill_replica as usize >= self.replicas
        {
            bail!(
                "faults.kill_replica {} out of range (cluster has {} replicas)",
                self.fault_kill_replica,
                self.replicas
            );
        }
        Ok(())
    }

    /// The fault-injection plan from the `[faults]` section, or `None`
    /// when nothing is injected — the usual, healthy case.
    pub fn fault_plan(&self) -> Option<crate::io::FaultPlan> {
        let plan = crate::io::FaultPlan {
            seed: self.fault_seed,
            transient: self.fault_transient,
            transient_attempts: self.fault_transient_attempts,
            loss: self.fault_loss,
            corrupt: self.fault_corrupt,
            spike: self.fault_spike,
            spike_seconds: self.fault_spike_seconds,
            kill_replica: usize::try_from(self.fault_kill_replica).ok(),
            kill_after: self.fault_kill_after,
        };
        plan.any().then_some(plan)
    }

    /// Transfer-engine sizing from the `[io]` section.
    pub fn io_config(&self) -> crate::io::IoConfig {
        crate::io::IoConfig {
            workers: self.io_workers,
            demand_depth: self.io_demand_depth,
            prefetch_depth: self.io_prefetch_depth,
            retries: self.io_retries,
            retry_backoff_ms: self.io_retry_backoff_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_overrides() {
        let text = r#"
model = "llama2-13b"
[cache]
chunk_tokens = 128
dram_bytes = 1GiB
policy = "lru"
indexed_eviction = false
[workload]
rate = 1.0
oversample = false
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.model, "llama2-13b");
        assert_eq!(cfg.chunk_tokens, 128);
        assert_eq!(cfg.dram_bytes, 1 << 30);
        assert_eq!(cfg.policy, "lru");
        assert!(!cfg.indexed_eviction);
        assert_eq!(cfg.rate, 1.0);
        assert!(!cfg.oversample);
        cfg.validate().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let map = file::parse("bogus = 1").unwrap();
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.apply(&map).is_err());
    }

    #[test]
    fn validation_catches_bad_names() {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "gpt-17".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.system = "magic".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.overlap = "diagonal".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn policy_errors_list_registered_names() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = "arc".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::cache::policy::registry::NAMES {
            assert!(msg.contains(name), "policy error missing '{name}': {msg}");
        }
        let mut cfg = ExperimentConfig::default();
        cfg.prefetch_strategy = "psychic".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::cache::prefetch::registry::NAMES {
            assert!(msg.contains(name), "strategy error missing '{name}': {msg}");
        }
    }

    #[test]
    fn policy_names_are_case_insensitive() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = "SLRU".into();
        cfg.prefetch_strategy = "Depth-Bounded:4".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn io_section_keys() {
        let text = r#"
[io]
workers = 4
demand_depth = 32
prefetch_depth = 128
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.io_workers, 4);
        assert_eq!(cfg.io_demand_depth, 32);
        assert_eq!(cfg.io_prefetch_depth, 128);
        cfg.validate().unwrap();
        let io = cfg.io_config();
        assert_eq!(io.workers, 4);
        assert_eq!(io.demand_depth, 32);
        assert_eq!(io.prefetch_depth, 128);
        cfg.io_workers = 0;
        assert!(cfg.validate().is_err(), "zero workers must be rejected");
    }

    #[test]
    fn cluster_section_keys() {
        let text = r#"
[cluster]
replicas = 4
router = "affinity-balanced:0.25"
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.router, "affinity-balanced:0.25");
        cfg.validate().unwrap();
        cfg.replicas = 0;
        assert!(cfg.validate().is_err(), "zero replicas must be rejected");
        cfg.replicas = 65;
        assert!(cfg.validate().is_err(), "directory mask is 64 bits wide");
        cfg.replicas = 4;
        cfg.router = "hash-ring".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::cluster::router::registry::NAMES {
            assert!(msg.contains(name), "router error missing '{name}': {msg}");
        }
    }

    #[test]
    fn system_errors_list_registered_names() {
        let mut cfg = ExperimentConfig::default();
        cfg.system = "orca".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::serve::system::SystemSpec::NAMES {
            assert!(msg.contains(name), "system error missing '{name}': {msg}");
        }
    }

    #[test]
    fn faults_section_keys_and_plan() {
        // no [faults] section → no plan: the healthy path stays free
        assert!(ExperimentConfig::default().fault_plan().is_none());
        let text = r#"
[io]
retries = 3
retry_backoff_ms = 2
[faults]
seed = 99
transient = 0.1
transient_attempts = 2
loss = 0.01
corrupt = 0.02
spike = 0.05
spike_seconds = 0.2
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.io_retries, 3);
        assert_eq!(cfg.io_config().retries, 3);
        assert_eq!(cfg.io_config().retry_backoff_ms, 2);
        let plan = cfg.fault_plan().expect("plan enabled");
        assert_eq!(plan.seed, 99);
        assert_eq!(plan.transient_attempts, 2);
        assert!((plan.loss - 0.01).abs() < 1e-12);
        assert!(plan.kill_replica.is_none());
        assert!(plan.enabled());
    }

    #[test]
    fn fault_validation_rejects_bad_rates_and_kill_targets() {
        let mut cfg = ExperimentConfig::default();
        cfg.fault_loss = 1.5;
        assert!(cfg.validate().is_err(), "rates above 1 rejected");
        let mut cfg = ExperimentConfig::default();
        cfg.fault_transient = -0.1;
        assert!(cfg.validate().is_err(), "negative rates rejected");
        let mut cfg = ExperimentConfig::default();
        cfg.replicas = 2;
        cfg.fault_kill_replica = 2;
        assert!(cfg.validate().is_err(), "kill target beyond the fleet");
        cfg.fault_kill_replica = 1;
        cfg.validate().unwrap();
        let plan = cfg.fault_plan().expect("kill alone still makes a plan");
        assert_eq!(plan.kill_replica, Some(1));
        assert!(!plan.enabled(), "no chunk-level faults");
        assert!(plan.any());
    }

    #[test]
    fn obs_section_keys() {
        // off by default: runs stay un-instrumented unless asked
        let d = ExperimentConfig::default();
        assert!(!d.obs_trace && !d.obs_timeline);
        let text = r#"
[obs]
trace = true
trace_capacity = 1024
timeline = true
timeline_interval = 0.25
flight_depth = 32
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert!(cfg.obs_trace);
        assert_eq!(cfg.obs_trace_capacity, 1024);
        assert!(cfg.obs_timeline);
        assert!((cfg.obs_timeline_interval - 0.25).abs() < 1e-12);
        assert_eq!(cfg.obs_flight_depth, 32);
        cfg.validate().unwrap();
        cfg.obs_trace_capacity = 0;
        assert!(cfg.validate().is_err(), "zero-capacity ring rejected");
        cfg.obs_trace_capacity = 1024;
        cfg.obs_timeline_interval = 0.0;
        assert!(cfg.validate().is_err(), "zero interval rejected");
    }

    #[test]
    fn prefetch_section_keys() {
        let text = r#"
[cache]
policy = "2q"
[prefetch]
strategy = "depth-bounded:2"
window = 6
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.policy, "2q");
        assert_eq!(cfg.prefetch_strategy, "depth-bounded:2");
        assert_eq!(cfg.prefetch_window, 6);
        cfg.validate().unwrap();
    }
}
