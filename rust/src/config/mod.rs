//! Experiment configuration: one struct that pins down everything a
//! serving run needs — model, platform, cache geometry, policies,
//! workload shape, arrival process — loadable from a TOML-subset file
//! ([`file`]) and overridable from the CLI. Every experiment in
//! `rust/benches/` is a set of `ExperimentConfig` values, so paper
//! figures replay from config alone.

pub mod file;

use crate::config::file::{ConfigMap, Value};
use anyhow::{bail, Context, Result};

/// Full configuration of one serving experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // --- what is served, on what ---
    /// Model spec name (see `hw::spec::model_specs`).
    pub model: String,
    /// Platform spec name (`a6000` | `rtx4090`).
    pub platform: String,
    /// System variant: `vllm` | `ccache` | `sccache` | `lmcache` | `pcr`.
    pub system: String,

    // --- cache engine ---
    /// Cache chunk granularity in tokens (paper: 256).
    pub chunk_tokens: usize,
    /// GPU KV capacity in bytes (0 = platform budget after weights).
    /// Overriding below the platform budget emulates co-located memory
    /// pressure and is how tests exercise the full tier hierarchy.
    pub gpu_bytes: u64,
    /// DRAM KV capacity in bytes (0 = platform CPU memory budget).
    pub dram_bytes: u64,
    /// SSD KV capacity in bytes (0 = platform SSD budget).
    pub ssd_bytes: u64,
    /// Eviction policy name, resolved through
    /// `cache::policy::registry` (case-insensitive). Empty = use the
    /// system variant's default (e.g. `pcr` runs look-ahead LRU, the
    /// baselines run LRU).
    pub policy: String,
    /// Look-ahead LRU horizon: queued requests examined for protection.
    pub lookahead_window: usize,
    /// Queue-based prefetch window (paper: 4; Fig 18 sweeps it).
    pub prefetch_window: usize,
    /// Prefetch strategy name, resolved through
    /// `cache::prefetch::registry` (case-insensitive;
    /// `depth-bounded:<n>` is accepted). Empty = the system variant's
    /// default (`queue-window` for prefetching systems, else `none`).
    pub prefetch_strategy: String,
    /// Layer-wise overlap mode: `sync` | `only-up` | `only-down` | `up-down`.
    pub overlap: String,
    /// Use batched chunk copies (`cudaMemcpyBatchAsync` analogue).
    pub batch_async: bool,
    /// Select eviction victims through the incremental per-tier rank
    /// index (amortized O(log n); §Perf iteration 3) instead of the
    /// fused O(n) scan. On by default; the off position is the A/B
    /// baseline for benches and parity tests.
    pub indexed_eviction: bool,

    // --- transfer engine (`[io]` section) ---
    /// Dedicated I/O worker threads for the real-path transfer engine.
    pub io_workers: usize,
    /// Bound on queued demand tickets (backpressure beyond it).
    pub io_demand_depth: usize,
    /// Bound on in-flight prefetch loads (both the real engine's queue
    /// and the simulator's in-flight window).
    pub io_prefetch_depth: usize,

    // --- cluster serving (`[cluster]` section) ---
    /// Serving replicas driven by `cluster::sim` (1 = the single-engine
    /// path). Bounded by the directory's replica-set word width (64).
    pub replicas: usize,
    /// Routing policy name, resolved through
    /// `cluster::router::registry` (case-insensitive;
    /// `affinity-balanced:<alpha>` is accepted).
    pub router: String,

    // --- workload (paper §6.1) ---
    /// Distinct inputs in the dataset (paper: 1000 / 2000).
    pub n_inputs: usize,
    /// Sample requests with replacement (workload 1) or shuffle-cycle
    /// without (workload 2).
    pub oversample: bool,
    /// Total requests issued (paper: 2000 sampling iterations).
    pub n_requests: usize,
    /// Poisson arrival rate, requests/second.
    pub rate: f64,
    /// Documents retrieved per query (paper: 2).
    pub docs_per_query: usize,
    /// Query length in tokens.
    pub query_tokens: usize,
    /// Output tokens per request (paper: 16, prefill-focused).
    pub output_tokens: usize,

    // --- corpus ---
    pub n_docs: usize,
    pub n_topics: usize,
    /// Mean document length in tokens (2 docs + query ≈ 6.8k as in the
    /// paper).
    pub mean_doc_tokens: usize,

    /// Master seed (forked per component).
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "llama3.1-8b".into(),
            platform: "a6000".into(),
            system: "pcr".into(),
            chunk_tokens: 256,
            gpu_bytes: 0,
            dram_bytes: 0,
            ssd_bytes: 0,
            policy: String::new(),
            lookahead_window: 4,
            prefetch_window: 4,
            prefetch_strategy: String::new(),
            overlap: "up-down".into(),
            batch_async: true,
            indexed_eviction: true,
            io_workers: 2,
            io_demand_depth: 64,
            io_prefetch_depth: 64,
            replicas: 1,
            router: "prefix-affinity".into(),
            n_inputs: 1000,
            oversample: true,
            n_requests: 2000,
            rate: 0.5,
            docs_per_query: 2,
            query_tokens: 64,
            output_tokens: 16,
            n_docs: 4000,
            n_topics: 128,
            mean_doc_tokens: 3368, // 2*3368 + 64 ≈ 6.8k tokens
            seed: 20260710,
        }
    }
}

impl ExperimentConfig {
    /// Apply overrides from a parsed config map (`section.key` keys —
    /// see module docs of [`file`] for the accepted sections).
    pub fn apply(&mut self, map: &ConfigMap) -> Result<()> {
        for (key, val) in map {
            self.apply_one(key, val)
                .with_context(|| format!("config key '{key}'"))?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, val: &Value) -> Result<()> {
        let need_str = || -> Result<String> {
            val.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow::anyhow!("expected string"))
        };
        let need_f64 = || -> Result<f64> {
            val.as_f64().ok_or_else(|| anyhow::anyhow!("expected number"))
        };
        let need_bool = || -> Result<bool> {
            val.as_bool().ok_or_else(|| anyhow::anyhow!("expected bool"))
        };
        match key {
            "serve.model" | "model" => self.model = need_str()?,
            "serve.platform" | "platform" => self.platform = need_str()?,
            "serve.system" | "system" => self.system = need_str()?,
            "cache.chunk_tokens" => self.chunk_tokens = need_f64()? as usize,
            "cache.gpu_bytes" => self.gpu_bytes = need_f64()? as u64,
            "cache.dram_bytes" => self.dram_bytes = need_f64()? as u64,
            "cache.ssd_bytes" => self.ssd_bytes = need_f64()? as u64,
            "cache.policy" => self.policy = need_str()?,
            "cache.lookahead_window" => self.lookahead_window = need_f64()? as usize,
            "cache.prefetch_window" | "prefetch.window" => {
                self.prefetch_window = need_f64()? as usize
            }
            "prefetch.strategy" => self.prefetch_strategy = need_str()?,
            "cache.overlap" => self.overlap = need_str()?,
            "cache.batch_async" => self.batch_async = need_bool()?,
            "cache.indexed_eviction" => self.indexed_eviction = need_bool()?,
            "io.workers" => self.io_workers = need_f64()? as usize,
            "io.demand_depth" => self.io_demand_depth = need_f64()? as usize,
            "io.prefetch_depth" => self.io_prefetch_depth = need_f64()? as usize,
            "cluster.replicas" => self.replicas = need_f64()? as usize,
            "cluster.router" => self.router = need_str()?,
            "workload.n_inputs" => self.n_inputs = need_f64()? as usize,
            "workload.oversample" => self.oversample = need_bool()?,
            "workload.n_requests" => self.n_requests = need_f64()? as usize,
            "workload.rate" => self.rate = need_f64()?,
            "workload.docs_per_query" => self.docs_per_query = need_f64()? as usize,
            "workload.query_tokens" => self.query_tokens = need_f64()? as usize,
            "workload.output_tokens" => self.output_tokens = need_f64()? as usize,
            "corpus.n_docs" => self.n_docs = need_f64()? as usize,
            "corpus.n_topics" => self.n_topics = need_f64()? as usize,
            "corpus.mean_doc_tokens" => self.mean_doc_tokens = need_f64()? as usize,
            "seed" => self.seed = need_f64()? as u64,
            _ => bail!("unknown config key"),
        }
        Ok(())
    }

    /// Load defaults + file overrides.
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let map = file::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        use crate::cache::{policy, prefetch};
        use crate::cluster::directory as cluster_directory;
        use crate::cluster::router::registry as router_registry;
        use crate::hw::spec::{model_spec, platform_spec};
        use crate::serve::system::SystemSpec;
        use crate::sim::pipeline::OverlapMode;
        if model_spec(&self.model).is_none() {
            bail!("unknown model '{}'", self.model);
        }
        if platform_spec(&self.platform).is_none() {
            bail!("unknown platform '{}'", self.platform);
        }
        if !self.policy.is_empty() && policy::registry::parse(&self.policy).is_none() {
            bail!(
                "unknown policy '{}' (registered: {})",
                self.policy,
                policy::registry::names_joined()
            );
        }
        if !self.prefetch_strategy.is_empty()
            && prefetch::registry::parse(&self.prefetch_strategy).is_none()
        {
            bail!(
                "unknown prefetch strategy '{}' (registered: {})",
                self.prefetch_strategy,
                prefetch::registry::names_joined()
            );
        }
        if OverlapMode::parse(&self.overlap).is_none() {
            bail!("unknown overlap mode '{}'", self.overlap);
        }
        if !SystemSpec::NAMES.contains(&self.system.as_str()) {
            bail!(
                "unknown system '{}' (registered: {})",
                self.system,
                SystemSpec::names_joined()
            );
        }
        if self.chunk_tokens == 0 || self.rate <= 0.0 || self.n_requests == 0 {
            bail!("degenerate workload parameters");
        }
        if self.io_workers == 0 || self.io_demand_depth == 0 || self.io_prefetch_depth == 0 {
            bail!("io.workers / io.demand_depth / io.prefetch_depth must be >= 1");
        }
        if self.replicas == 0 || self.replicas > cluster_directory::MAX_REPLICAS {
            bail!(
                "cluster.replicas must be in 1..={} (got {})",
                cluster_directory::MAX_REPLICAS,
                self.replicas
            );
        }
        if router_registry::parse(&self.router).is_none() {
            bail!(
                "unknown router '{}' (registered: {})",
                self.router,
                router_registry::names_joined()
            );
        }
        Ok(())
    }

    /// Transfer-engine sizing from the `[io]` section.
    pub fn io_config(&self) -> crate::io::IoConfig {
        crate::io::IoConfig {
            workers: self.io_workers,
            demand_depth: self.io_demand_depth,
            prefetch_depth: self.io_prefetch_depth,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_overrides() {
        let text = r#"
model = "llama2-13b"
[cache]
chunk_tokens = 128
dram_bytes = 1GiB
policy = "lru"
indexed_eviction = false
[workload]
rate = 1.0
oversample = false
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.model, "llama2-13b");
        assert_eq!(cfg.chunk_tokens, 128);
        assert_eq!(cfg.dram_bytes, 1 << 30);
        assert_eq!(cfg.policy, "lru");
        assert!(!cfg.indexed_eviction);
        assert_eq!(cfg.rate, 1.0);
        assert!(!cfg.oversample);
        cfg.validate().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let map = file::parse("bogus = 1").unwrap();
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.apply(&map).is_err());
    }

    #[test]
    fn validation_catches_bad_names() {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "gpt-17".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.system = "magic".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.overlap = "diagonal".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn policy_errors_list_registered_names() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = "arc".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::cache::policy::registry::NAMES {
            assert!(msg.contains(name), "policy error missing '{name}': {msg}");
        }
        let mut cfg = ExperimentConfig::default();
        cfg.prefetch_strategy = "psychic".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::cache::prefetch::registry::NAMES {
            assert!(msg.contains(name), "strategy error missing '{name}': {msg}");
        }
    }

    #[test]
    fn policy_names_are_case_insensitive() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = "SLRU".into();
        cfg.prefetch_strategy = "Depth-Bounded:4".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn io_section_keys() {
        let text = r#"
[io]
workers = 4
demand_depth = 32
prefetch_depth = 128
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.io_workers, 4);
        assert_eq!(cfg.io_demand_depth, 32);
        assert_eq!(cfg.io_prefetch_depth, 128);
        cfg.validate().unwrap();
        let io = cfg.io_config();
        assert_eq!(io.workers, 4);
        assert_eq!(io.demand_depth, 32);
        assert_eq!(io.prefetch_depth, 128);
        cfg.io_workers = 0;
        assert!(cfg.validate().is_err(), "zero workers must be rejected");
    }

    #[test]
    fn cluster_section_keys() {
        let text = r#"
[cluster]
replicas = 4
router = "affinity-balanced:0.25"
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.router, "affinity-balanced:0.25");
        cfg.validate().unwrap();
        cfg.replicas = 0;
        assert!(cfg.validate().is_err(), "zero replicas must be rejected");
        cfg.replicas = 65;
        assert!(cfg.validate().is_err(), "directory mask is 64 bits wide");
        cfg.replicas = 4;
        cfg.router = "hash-ring".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::cluster::router::registry::NAMES {
            assert!(msg.contains(name), "router error missing '{name}': {msg}");
        }
    }

    #[test]
    fn system_errors_list_registered_names() {
        let mut cfg = ExperimentConfig::default();
        cfg.system = "orca".into();
        let msg = format!("{:#}", cfg.validate().unwrap_err());
        for name in crate::serve::system::SystemSpec::NAMES {
            assert!(msg.contains(name), "system error missing '{name}': {msg}");
        }
    }

    #[test]
    fn prefetch_section_keys() {
        let text = r#"
[cache]
policy = "2q"
[prefetch]
strategy = "depth-bounded:2"
window = 6
"#;
        let map = file::parse(text).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.policy, "2q");
        assert_eq!(cfg.prefetch_strategy, "depth-bounded:2");
        assert_eq!(cfg.prefetch_window, 6);
        cfg.validate().unwrap();
    }
}
