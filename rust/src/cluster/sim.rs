//! The cluster simulator: N replicas, one workload, one router.
//!
//! Virtual-time scheduling rule: the replica with the **smallest
//! clock** acts next (ties to the lowest id), and before it acts every
//! arrival whose retrieval completed at or before that clock is routed
//! — so a routing decision never observes queue state from any
//! replica's future, and with one replica the loop is structurally
//! identical to `serve::engine::run` (the single-replica parity test
//! pins this down to the exact metric values).

use crate::cluster::directory::PrefixDirectory;
use crate::cluster::replica::Replica;
use crate::cluster::router::{registry, RoutingPolicy};
use crate::config::ExperimentConfig;
use crate::obs::trace::{Kind, Phase, TraceEvent, Track};
use crate::serve::engine::RunOutcome;
use crate::serve::metrics::{MetricsCollector, Report};
use crate::serve::request::Request;
use crate::serve::system::SystemSpec;
use crate::serve::workload::Workload;
use std::sync::Arc;

/// Per-replica outcomes plus the fleet-level aggregates.
#[derive(Clone, Debug)]
pub struct ClusterOutcome {
    /// Routing policy that produced this run.
    pub router: &'static str,
    /// One full single-engine outcome per replica, in id order.
    pub replicas: Vec<RunOutcome>,
    /// All replicas' samples merged into one report.
    pub aggregate: Report,
    /// Fleet cache hit ratio: Σ hit chunks / Σ looked-up chunks.
    pub hit_ratio: f64,
    /// Coefficient of variation of per-replica finished counts
    /// (0 = perfectly even, grows with skew).
    pub load_imbalance: f64,
    /// Requests whose directory-predicted matched prefix had shrunk by
    /// prefill time (eviction between routing and scheduling).
    pub directory_stale: u64,
    /// Live chunk entries left in the directory at the end.
    pub directory_entries: usize,
    /// Latest replica clock — the fleet's makespan.
    pub virtual_duration: f64,
    /// Requests re-routed off a failed replica (0 without a kill plan).
    /// Also folded into `aggregate.degrade.failovers`.
    pub failovers: u64,
}

/// Run the cluster configured by `cfg` (`cluster.replicas`,
/// `cluster.router`). The router name must be registered —
/// `Config::validate` guarantees that upstream.
pub fn run(cfg: &ExperimentConfig, spec: &SystemSpec, workload: &Workload) -> ClusterOutcome {
    let router = registry::parse(&cfg.router).unwrap_or_else(|| {
        panic!(
            "unknown router '{}' (registered: {})",
            cfg.router,
            registry::names_joined()
        )
    });
    run_with(cfg, spec, workload, cfg.replicas, router)
}

/// Run `n_replicas` copies of `cfg` × `spec` over `workload` under an
/// explicit routing policy (the entry point for unregistered custom
/// policies and the router-sweep bench).
pub fn run_with(
    cfg: &ExperimentConfig,
    spec: &SystemSpec,
    workload: &Workload,
    n_replicas: usize,
    mut router: Box<dyn RoutingPolicy>,
) -> ClusterOutcome {
    let n = n_replicas.max(1);
    let mut directory = PrefixDirectory::new(n);
    let mut replicas: Vec<Replica> = (0..n)
        .map(|id| Replica::new(id, cfg, spec, workload.mean_input_tokens))
        .collect();
    let items = &workload.items;
    let ready = |i: usize| items[i].arrival + items[i].retrieval_seconds;
    let mut next = 0usize;

    // fault injection: a pending replica kill from the `[faults]`
    // section. Fires once `kill_after` requests have been routed; a
    // kill that would leave the fleet empty (or targets a replica this
    // run doesn't have) is ignored.
    let mut alive = vec![true; n];
    let mut pending_kill = cfg
        .fault_plan()
        .and_then(|p| p.kill_replica.map(|r| (r, p.kill_after)))
        .filter(|&(r, _)| r < n && n > 1);
    let mut routed = 0u64;
    let mut failovers = 0u64;

    loop {
        // a due kill fires before anything else observes the fleet:
        // the dead replica's open requests are evacuated, its holder
        // bits cleared, and each evacuee re-routed over the survivors
        if let Some((kr, after)) = pending_kill {
            if routed >= after {
                pending_kill = None;
                alive[kr] = false;
                for req in replicas[kr].fail(&mut directory) {
                    failovers += 1;
                    route_one(router.as_mut(), &mut replicas, &alive, &directory, req);
                }
            }
        }

        // the smallest-clock live replica acts next; a replica that is
        // idle with no arrivals left is retired from consideration
        let Some(r) = replicas
            .iter()
            .filter(|rep| alive[rep.id] && !(rep.is_idle() && next >= items.len()))
            .min_by(|a, b| {
                a.clock().partial_cmp(&b.clock()).unwrap().then(a.id.cmp(&b.id))
            })
            .map(|rep| rep.id)
        else {
            break;
        };

        // route every arrival whose retrieval completed by its clock
        while next < items.len() && ready(next) <= replicas[r].clock() {
            let it = &items[next];
            let req = Request::new(
                next as u64,
                it.input_id,
                Arc::clone(&it.tokens),
                Arc::clone(&it.chain),
                cfg.output_tokens,
                it.arrival,
                ready(next),
            );
            route_one(router.as_mut(), &mut replicas, &alive, &directory, req);
            next += 1;
            routed += 1;
            if let Some((kr, after)) = pending_kill {
                if routed >= after {
                    pending_kill = None;
                    alive[kr] = false;
                    for req in replicas[kr].fail(&mut directory) {
                        failovers += 1;
                        route_one(router.as_mut(), &mut replicas, &alive, &directory, req);
                    }
                }
            }
        }

        if !alive[r] {
            // the routing loop's kill check took down the very replica
            // picked to act — its work is already re-routed
            continue;
        }
        if replicas[r].is_idle() {
            // nothing routed to it at its clock: jump forward to the
            // next admission (strictly forward — the routing loop just
            // drained everything at or before the current clock)
            if next < items.len() {
                replicas[r].core.clock = ready(next);
            }
            continue;
        }
        replicas[r].step(&mut directory);
    }

    #[cfg(debug_assertions)]
    {
        let engines: Vec<&crate::cache::engine::CacheEngine> =
            replicas.iter().map(|rep| &rep.core.cache).collect();
        if let Err(msg) = directory.check_consistent_alive(&engines, &alive) {
            panic!("directory drifted from replica trees: {msg}");
        }
    }

    let mut merged = MetricsCollector::new();
    let mut directory_stale = 0u64;
    let mut hit_chunks = 0u64;
    let mut total_chunks = 0u64;
    let mut finished_counts = Vec::with_capacity(n);
    for rep in &replicas {
        merged.absorb(&rep.core.metrics);
        directory_stale += rep.core.directory_stale;
        hit_chunks += rep.core.cache.stats.total_hits();
        total_chunks += rep.core.cache.stats.total_hits() + rep.core.cache.stats.missed_chunks;
        finished_counts.push(rep.core.metrics.finished as f64);
    }
    // failover is a cluster-level event — no single replica owns it
    merged.degrade.failovers += failovers;
    let directory_entries = directory.len();
    let outcomes: Vec<RunOutcome> = replicas.into_iter().map(Replica::into_outcome).collect();
    // per-replica metrics.io is only set at finalization — fold the
    // lane counters from the outcomes, after absorbing the raw samples
    for out in &outcomes {
        merged.io.absorb(&out.io);
    }
    debug_assert_eq!(merged.finished, items.len(), "all requests must finish");

    let aggregate = merged.report();
    let hit_ratio = if total_chunks == 0 {
        0.0
    } else {
        hit_chunks as f64 / total_chunks as f64
    };
    let virtual_duration = outcomes.iter().fold(0.0f64, |acc, o| acc.max(o.virtual_duration));

    ClusterOutcome {
        router: router.name(),
        replicas: outcomes,
        aggregate,
        hit_ratio,
        load_imbalance: coefficient_of_variation(&finished_counts),
        directory_stale,
        directory_entries,
        virtual_duration,
        failovers,
    }
}

/// Route one request over the live replicas: the router answers with a
/// position into the alive-filtered views, which resolves to a replica
/// id (sparse after a failure — see the `RoutingPolicy` contract).
fn route_one(
    router: &mut dyn RoutingPolicy,
    replicas: &mut [Replica],
    alive: &[bool],
    directory: &PrefixDirectory,
    mut req: Request,
) {
    let views: Vec<_> = replicas
        .iter()
        .filter(|rep| alive[rep.id])
        .map(Replica::view)
        .collect();
    let pos = router.route(&req.chain.keys, &views, directory).min(views.len() - 1);
    let target = views[pos].id;
    req.routed_matched = Some(directory.matched_prefix_one(target, &req.chain.keys));
    // routing decisions land on the chosen replica's router track, at
    // the virtual instant the request became routable
    let (rid, t) = (req.id, req.queued_at);
    replicas[target].core.tracer.emit(|| TraceEvent {
        t,
        track: Track::Router,
        kind: Kind::Route,
        id: rid,
        phase: Phase::Instant,
    });
    replicas[target].enqueue(req);
}

/// Population coefficient of variation (σ/μ); 0 for empty input or a
/// zero mean.
fn coefficient_of_variation(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if mean == 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine;

    /// Same shape as `serve::engine`'s test workload: small tiers so
    /// eviction/prefetch fire, SSD holds the whole dataset.
    fn test_cfg(rate: f64) -> ExperimentConfig {
        ExperimentConfig {
            model: "llama2-7b".into(),
            platform: "a6000".into(),
            system: "pcr".into(),
            n_inputs: 40,
            n_requests: 120,
            oversample: true,
            rate,
            n_docs: 150,
            n_topics: 12,
            mean_doc_tokens: 600,
            query_tokens: 48,
            chunk_tokens: 256,
            gpu_bytes: 2 * (1 << 30),
            dram_bytes: 6 * (1 << 30),
            ssd_bytes: 40 * (1 << 30),
            ..Default::default()
        }
    }

    fn pcr_spec(cfg: &ExperimentConfig) -> SystemSpec {
        SystemSpec::try_named("pcr", cfg.prefetch_window).unwrap()
    }

    /// Satellite 3: one replica under round-robin reproduces the
    /// single-engine run exactly — same seed, same clocks, same
    /// counters — so the cluster layer adds no behavioural drift.
    #[test]
    fn single_replica_round_robin_matches_engine_run() {
        let cfg = test_cfg(0.8);
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let single = engine::run(&cfg, &spec, &wl);
        let cluster = run_with(&cfg, &spec, &wl, 1, registry::parse("round-robin").unwrap());
        assert_eq!(cluster.replicas.len(), 1);
        let rep = &cluster.replicas[0];
        assert_eq!(rep.report.finished, single.report.finished);
        assert_eq!(rep.report.ttft.mean, single.report.ttft.mean);
        assert_eq!(rep.report.e2el.p99, single.report.e2el.p99);
        assert_eq!(rep.report.itl.n, single.report.itl.n);
        assert_eq!(rep.report.queue_time.mean, single.report.queue_time.mean);
        assert_eq!(rep.report.retrieval.mean, single.report.retrieval.mean);
        assert_eq!(rep.cache.total_hits(), single.cache.total_hits());
        assert_eq!(rep.cache.evicted_chunks, single.cache.evicted_chunks);
        assert_eq!(rep.prefetch_submitted, single.prefetch_submitted);
        assert_eq!(rep.io.demand.submitted, single.io.demand.submitted);
        assert_eq!(rep.io.upgraded, single.io.upgraded);
        assert_eq!(rep.virtual_duration, single.virtual_duration);
        assert_eq!(cluster.virtual_duration, single.virtual_duration);
        // aggregates of one replica are that replica
        assert_eq!(cluster.aggregate.ttft.mean, single.report.ttft.mean);
        assert_eq!(cluster.load_imbalance, 0.0);
    }

    /// The PR's headline claim: affinity routing recovers the hit
    /// ratio that spraying repeats across the fleet destroys.
    #[test]
    fn affinity_routers_beat_round_robin_on_aggregate_hits() {
        let cfg = test_cfg(1.0);
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let rr = run_with(&cfg, &spec, &wl, 4, registry::parse("round-robin").unwrap());
        let pa = run_with(&cfg, &spec, &wl, 4, registry::parse("prefix-affinity").unwrap());
        let ab = run_with(&cfg, &spec, &wl, 4, registry::parse("affinity-balanced").unwrap());
        assert!(
            pa.hit_ratio > rr.hit_ratio,
            "prefix-affinity {:.3} vs round-robin {:.3}",
            pa.hit_ratio,
            rr.hit_ratio
        );
        assert!(
            ab.hit_ratio > rr.hit_ratio,
            "affinity-balanced {:.3} vs round-robin {:.3}",
            ab.hit_ratio,
            rr.hit_ratio
        );
    }

    #[test]
    fn all_routers_finish_everything() {
        let cfg = test_cfg(1.0);
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        for name in registry::NAMES {
            let out = run_with(&cfg, &spec, &wl, 3, registry::parse(name).unwrap());
            assert_eq!(out.aggregate.finished, 120, "{name}");
            assert_eq!(out.router, name);
            assert_eq!(out.replicas.len(), 3);
            assert!(out.virtual_duration > 0.0, "{name}");
        }
    }

    #[test]
    fn killed_at_start_replica_serves_nothing_yet_fleet_finishes() {
        let mut cfg = test_cfg(1.0);
        cfg.fault_kill_replica = 1;
        cfg.fault_kill_after = 0; // dies before any request is routed
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let out = run_with(&cfg, &spec, &wl, 3, registry::parse("round-robin").unwrap());
        assert_eq!(out.aggregate.finished, 120, "the fleet absorbs the loss");
        assert_eq!(out.replicas[1].report.finished, 0, "dead replica served nothing");
        assert!(out.replicas[0].report.finished > 0);
        assert!(out.replicas[2].report.finished > 0);
        // nobody was mid-flight at a kill that fires before routing
        assert_eq!(out.failovers, 0);
        assert_eq!(out.aggregate.degrade.failovers, 0);
    }

    #[test]
    fn mid_run_kill_reroutes_open_requests_and_loses_none() {
        let mut cfg = test_cfg(2.0); // high rate → deep queues at kill time
        cfg.fault_kill_replica = 1;
        cfg.fault_kill_after = 60;
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let out = run_with(&cfg, &spec, &wl, 3, registry::parse("round-robin").unwrap());
        assert_eq!(out.aggregate.finished, 120, "failover must not lose requests");
        assert!(out.failovers > 0, "the killed replica had open work");
        assert_eq!(out.aggregate.degrade.failovers, out.failovers);
        // the dead replica kept only what it finished before dying
        let dead_finished = out.replicas[1].report.finished;
        assert!(dead_finished < 40, "round-robin would have given it ~40");
        // per-replica finished counts still cover the whole workload
        let total: usize = out.replicas.iter().map(|r| r.report.finished).sum();
        assert_eq!(total, 120);
        assert!(out.aggregate.pretty().contains("failovers="));
    }

    #[test]
    fn failover_runs_replay_deterministically() {
        let mut cfg = test_cfg(2.0);
        cfg.fault_kill_replica = 0;
        cfg.fault_kill_after = 30;
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let a = run_with(&cfg, &spec, &wl, 3, registry::parse("affinity-balanced").unwrap());
        let b = run_with(&cfg, &spec, &wl, 3, registry::parse("affinity-balanced").unwrap());
        assert_eq!(a.aggregate.finished, 120);
        assert_eq!(a.failovers, b.failovers);
        assert_eq!(a.aggregate.ttft.mean, b.aggregate.ttft.mean);
        assert_eq!(a.directory_stale, b.directory_stale);
    }

    #[test]
    fn kill_that_would_empty_the_fleet_is_ignored() {
        let mut cfg = test_cfg(0.8);
        cfg.fault_kill_replica = 0;
        cfg.fault_kill_after = 0;
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        // single replica: killing it would strand the workload — ignored
        let out = run_with(&cfg, &spec, &wl, 1, registry::parse("round-robin").unwrap());
        assert_eq!(out.aggregate.finished, 120);
        assert_eq!(out.failovers, 0);
        assert_eq!(out.replicas[0].report.finished, 120);
    }

    #[test]
    fn cluster_traces_carry_routing_and_failover_events() {
        let mut cfg = test_cfg(2.0);
        cfg.obs_trace = true;
        cfg.fault_kill_replica = 1;
        cfg.fault_kill_after = 60;
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let a = run_with(&cfg, &spec, &wl, 3, registry::parse("round-robin").unwrap());
        let b = run_with(&cfg, &spec, &wl, 3, registry::parse("round-robin").unwrap());
        assert!(a.failovers > 0);
        for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(ra.trace, rb.trace, "same seed must replay byte-identically");
        }
        // every live replica saw routing decisions; the killed one
        // recorded the evacuation of its open requests
        for (i, rep) in a.replicas.iter().enumerate() {
            if i == 1 {
                assert!(rep.trace.iter().any(|e| e.kind == Kind::Failover), "replica {i}");
            } else {
                assert!(rep.trace.iter().any(|e| e.kind == Kind::Route), "replica {i}");
            }
        }
        // the fleet export is one Chrome doc, one pid per replica
        let views: Vec<(usize, &[TraceEvent])> =
            a.replicas.iter().enumerate().map(|(i, r)| (i, r.trace.as_slice())).collect();
        let views_b: Vec<(usize, &[TraceEvent])> =
            b.replicas.iter().enumerate().map(|(i, r)| (i, r.trace.as_slice())).collect();
        let doc = crate::obs::trace::chrome_trace(&views);
        assert_eq!(doc.dump(), crate::obs::trace::chrome_trace(&views_b).dump());
    }

    /// Breakdown rows stay exact under failover: an evacuated request
    /// re-runs its prefill on a survivor, so attempts may outnumber
    /// finishes, but every row still reconciles against its own TTFT.
    #[test]
    fn failover_breakdown_rows_reconcile() {
        let mut cfg = test_cfg(2.0);
        cfg.fault_kill_replica = 1;
        cfg.fault_kill_after = 60;
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let out = run_with(&cfg, &spec, &wl, 3, registry::parse("round-robin").unwrap());
        assert!(out.failovers > 0);
        let mut rows = 0usize;
        for rep in &out.replicas {
            assert!(rep.attribution.max_residual() < 1e-9);
            rows += rep.attribution.rows.len();
        }
        assert!(rows >= out.aggregate.finished, "{rows} rows < {}", out.aggregate.finished);
        assert!(out.aggregate.ttft_breakdown.any());
        assert!(out.aggregate.ttft_breakdown.n >= out.aggregate.finished);
    }

    #[test]
    fn cluster_replays_deterministically() {
        let cfg = test_cfg(1.0);
        let wl = Workload::build(&cfg);
        let spec = pcr_spec(&cfg);
        let a = run_with(&cfg, &spec, &wl, 4, registry::parse("affinity-balanced").unwrap());
        let b = run_with(&cfg, &spec, &wl, 4, registry::parse("affinity-balanced").unwrap());
        assert_eq!(a.aggregate.ttft.mean, b.aggregate.ttft.mean);
        assert_eq!(a.hit_ratio, b.hit_ratio);
        assert_eq!(a.directory_stale, b.directory_stale);
        for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(ra.report.finished, rb.report.finished);
        }
    }
}
