//! Multi-replica cluster serving — fleet-scale PCR.
//!
//! A single `serve::engine` instance owns one prefix tree, so a
//! request's longest cached prefix lives on exactly **one** replica;
//! under naive load balancing the repeat traffic that prefix reuse
//! feeds on is sprayed across the fleet and the aggregate hit ratio
//! collapses. This subsystem routes each request to the replica that
//! already holds its prefix, without ever letting the router walk a
//! replica-local tree:
//!
//! * [`replica`] — [`Replica`](replica::Replica): one full serving
//!   engine (`serve::engine::EngineCore`: cache + scheduler queue +
//!   prefetcher + `MetricsCollector`) behind a handle that republishes
//!   cache residency events after every step.
//! * [`directory`] — [`PrefixDirectory`](directory::PrefixDirectory):
//!   the global chunk-hash → replica-set map (one u64 bitmask per
//!   chunk), maintained purely from replica insert/evict callbacks
//!   ([`CacheEvent`](crate::cache::engine::CacheEvent)). Matched-prefix
//!   length per replica is answered in O(depth), for the whole fleet in
//!   O(depth + replicas).
//! * [`router`] — the open [`RoutingPolicy`](router::RoutingPolicy)
//!   trait + name registry (the same pattern as
//!   `cache::policy::registry`): `round-robin`, `least-loaded`,
//!   `prefix-affinity`, `affinity-balanced[:alpha]`.
//! * [`sim`] — drives N replicas over one `Workload` in virtual time
//!   (smallest-clock replica acts next, so routing never observes
//!   queue states from a replica's future) and aggregates per-replica
//!   outcomes into a [`ClusterOutcome`](sim::ClusterOutcome): fleet hit
//!   ratio, merged TTFT/E2EL summaries, load-imbalance coefficient,
//!   and directory staleness count.
//!
//! Configured via the `[cluster]` TOML section (`cluster.replicas`,
//! `cluster.router`) or `pcr cluster --replicas N --router NAME`.
//!
//! # Writing a custom routing policy
//!
//! Routing is an open extension point: implement
//! [`router::RoutingPolicy`] and either register a name (an arm in
//! `router::registry::parse` plus an entry in `registry::NAMES`, which
//! makes it reachable from TOML/CLI and the router-sweep bench) or
//! hand an instance straight to [`sim::run_with`]. The contract:
//!
//! * **`route`** picks a replica index in `0..views.len()` for a
//!   request whose chunk chain is `chain`. `views` is never empty and
//!   is ordered by replica id (`views[i].id == i`); out-of-range
//!   returns are clamped to the last replica rather than trusted.
//! * The router sees **only** [`router::ReplicaView`] (queue depths +
//!   virtual clock) and the [`directory`] — never a replica's tree.
//!   Keeping the observation surface this small is what makes the
//!   decision O(depth) instead of O(tree).
//! * Routers may keep internal state (`route` takes `&mut self`) —
//!   `round-robin`'s cursor is the canonical example. Determinism is
//!   required: same call sequence, same answers. Break ties
//!   deterministically (the built-ins use lowest load, then lowest id).
//!
//! A sticky-by-hash policy, condensed:
//!
//! ```ignore
//! #[derive(Debug, Default)]
//! struct StickyHash;
//!
//! impl RoutingPolicy for StickyHash {
//!     fn name(&self) -> &'static str { "sticky-hash" }
//!
//!     fn route(
//!         &mut self,
//!         chain: &[ChunkKey],
//!         views: &[ReplicaView],
//!         _directory: &PrefixDirectory,
//!     ) -> usize {
//!         // first chunk hash identifies the shared document prefix
//!         chain.first().map(|k| k.0 as usize).unwrap_or(0) % views.len()
//!     }
//! }
//!
//! // Unregistered use:
//! let out = sim::run_with(&cfg, &spec, &wl, 4, Box::new(StickyHash));
//! ```
//!
//! # Directory-consistency invariants
//!
//! The directory is a *mirror*, never an authority — replicas trust
//! only their local trees. The invariants, checked two-sidedly by
//! [`directory::PrefixDirectory::check_consistent`] and
//! property-tested under random insert/evict/route interleavings:
//!
//! 1. **No false holders**: every `(chunk, replica)` bit set in the
//!    directory corresponds to a node resident (≥1 tier) in that
//!    replica's tree.
//! 2. **No missing holders**: every resident node in every replica's
//!    tree has its bit set.
//! 3. **No empty entries**: a chunk whose holder mask reaches zero is
//!    removed from the map (so `len()` counts live chunks).
//!
//! These hold exactly *between* engine steps because residency changes
//! only inside [`CacheEngine`](crate::cache::engine::CacheEngine)
//! mutations, each of which emits a [`CacheEvent`]
//! (crate::cache::engine::CacheEvent) that
//! [`Replica::step`](replica::Replica::step) drains into the directory
//! before returning. *Within* the window between a routing decision
//! and the target replica's prefill, eviction pressure can still
//! shrink the promised prefix — that is not an inconsistency but
//! **staleness**, counted per replica
//! (`EngineCore::directory_stale`, surfaced as
//! [`ClusterOutcome::directory_stale`](sim::ClusterOutcome)) and
//! harmless for correctness because `plan_movement` re-checks the
//! local tree.

pub mod directory;
pub mod replica;
pub mod router;
pub mod sim;

pub use directory::PrefixDirectory;
pub use replica::Replica;
pub use router::{ReplicaView, RoutingPolicy};
pub use sim::{run, run_with, ClusterOutcome};
