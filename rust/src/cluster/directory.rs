//! The global prefix directory: chunk-hash → replica set.
//!
//! One `HashMap<ChunkKey, u64>` where each value is a bitmask of the
//! replicas holding a resident copy of that chunk (any tier). The map
//! is maintained *only* from replica residency events
//! ([`CacheEvent`]): routing never walks a replica-local tree, so a
//! placement decision costs O(chain depth) directory probes for one
//! replica and O(depth + replicas) for the whole fleet — independent
//! of tree sizes. See the module guide in [`crate::cluster`] for the
//! consistency invariants.

use crate::cache::chunk::ChunkKey;
use crate::cache::engine::{CacheEngine, CacheEvent};
use std::collections::HashMap;

/// Replica-set word width: one bit per replica in a `u64`.
pub const MAX_REPLICAS: usize = 64;

/// Global chunk-residency map for a fleet of up to [`MAX_REPLICAS`]
/// replicas.
#[derive(Clone, Debug)]
pub struct PrefixDirectory {
    /// chunk hash → bitmask of replicas holding a resident copy.
    /// Entries are removed when the mask reaches zero.
    holders: HashMap<ChunkKey, u64>,
    n_replicas: usize,
}

impl PrefixDirectory {
    /// A directory for `n_replicas` replicas (1..=[`MAX_REPLICAS`]).
    pub fn new(n_replicas: usize) -> PrefixDirectory {
        assert!(
            (1..=MAX_REPLICAS).contains(&n_replicas),
            "replicas must be in 1..={MAX_REPLICAS} (got {n_replicas})"
        );
        PrefixDirectory {
            holders: HashMap::new(),
            n_replicas,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.n_replicas
    }

    /// Distinct chunks with at least one resident replica copy.
    pub fn len(&self) -> usize {
        self.holders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.holders.is_empty()
    }

    /// Apply one replica's residency event (the insert/evict callback
    /// feed drained by `Replica::step`).
    pub fn apply(&mut self, replica: usize, event: &CacheEvent) {
        debug_assert!(replica < self.n_replicas);
        let bit = 1u64 << replica;
        match event {
            CacheEvent::Resident(key) => {
                *self.holders.entry(*key).or_insert(0) |= bit;
            }
            CacheEvent::Gone(key) => {
                if let Some(mask) = self.holders.get_mut(key) {
                    *mask &= !bit;
                    if *mask == 0 {
                        self.holders.remove(key);
                    }
                }
            }
        }
    }

    /// Bitmask of the replicas holding `key` (0 = nobody).
    pub fn holders(&self, key: ChunkKey) -> u64 {
        self.holders.get(&key).copied().unwrap_or(0)
    }

    /// Matched-prefix length of `chain` on ONE replica, in O(depth):
    /// the count of leading chunks whose holder mask has the replica's
    /// bit. The prefix must be contiguous from the root — one missing
    /// link ends the usable prefix, the same rule the replica-local
    /// tree's `match_chain` applies.
    pub fn matched_prefix_one(&self, replica: usize, chain: &[ChunkKey]) -> usize {
        debug_assert!(replica < self.n_replicas);
        let bit = 1u64 << replica;
        chain
            .iter()
            .take_while(|k| self.holders(**k) & bit != 0)
            .count()
    }

    /// Matched-prefix length of `chain` on EVERY replica in one
    /// O(depth + replicas) walk: AND the holder masks down the chain;
    /// a replica's matched length is the depth at which its bit drops
    /// out of the surviving set.
    pub fn matched_prefix_all(&self, chain: &[ChunkKey]) -> Vec<usize> {
        let full: u64 = if self.n_replicas == MAX_REPLICAS {
            u64::MAX
        } else {
            (1u64 << self.n_replicas) - 1
        };
        let mut lens = vec![0usize; self.n_replicas];
        let mut alive = full;
        for (depth, key) in chain.iter().enumerate() {
            let h = self.holders(*key);
            let mut dropped = alive & !h;
            while dropped != 0 {
                let r = dropped.trailing_zeros() as usize;
                lens[r] = depth;
                dropped &= dropped - 1;
            }
            alive &= h;
            if alive == 0 {
                return lens;
            }
        }
        // replicas still alive hold the entire chain
        let mut survivors = alive;
        while survivors != 0 {
            let r = survivors.trailing_zeros() as usize;
            lens[r] = chain.len();
            survivors &= survivors - 1;
        }
        lens
    }

    /// Drop every holder bit of `replica` (failover: a dead replica's
    /// cache must stop influencing placement). Entries whose mask
    /// reaches zero are removed, same as per-event `Gone` handling.
    pub fn clear_replica(&mut self, replica: usize) {
        debug_assert!(replica < self.n_replicas);
        let bit = 1u64 << replica;
        self.holders.retain(|_, mask| {
            *mask &= !bit;
            *mask != 0
        });
    }

    /// Two-sided consistency check against the replicas' actual trees
    /// (invariants 1–3 of the module guide). O(directory + Σ trees) —
    /// a test/debug facility, not a routing-path operation.
    pub fn check_consistent(&self, replicas: &[&CacheEngine]) -> Result<(), String> {
        self.check_consistent_alive(replicas, &vec![true; replicas.len()])
    }

    /// [`check_consistent`](Self::check_consistent) for a fleet with
    /// failures: a dead replica must hold *nothing* in the directory
    /// (its bits were cleared at failure), and its tree — frozen at
    /// the moment of death — is exempt from the no-missing-holders
    /// invariant.
    pub fn check_consistent_alive(
        &self,
        replicas: &[&CacheEngine],
        alive: &[bool],
    ) -> Result<(), String> {
        if replicas.len() != self.n_replicas || alive.len() != self.n_replicas {
            return Err(format!(
                "directory sized for {} replicas, given {} (alive mask {})",
                self.n_replicas,
                replicas.len(),
                alive.len()
            ));
        }
        // 1. no false holders, 3. no empty entries
        for (key, mask) in &self.holders {
            if *mask == 0 {
                return Err(format!("empty holder mask for {key:?} left in the map"));
            }
            let mut m = *mask;
            while m != 0 {
                let r = m.trailing_zeros() as usize;
                m &= m - 1;
                if !alive[r] {
                    return Err(format!(
                        "directory still claims dead replica {r} holds {key:?}"
                    ));
                }
                let resident = replicas[r]
                    .tree
                    .get(*key)
                    .map(|id| !replicas[r].tree.node(id).tiers.is_empty())
                    .unwrap_or(false);
                if !resident {
                    return Err(format!(
                        "directory claims replica {r} holds {key:?}; its tree disagrees"
                    ));
                }
            }
        }
        // 2. no missing holders (dead replicas exempt: their frozen
        // trees are deliberately absent from the directory)
        for (r, engine) in replicas.iter().enumerate() {
            if !alive[r] {
                continue;
            }
            for id in engine.tree.ids() {
                let node = engine.tree.node(id);
                if node.tiers.is_empty() {
                    continue;
                }
                if self.holders(node.key) & (1u64 << r) == 0 {
                    return Err(format!(
                        "replica {r} holds {:?}; the directory disagrees",
                        node.key
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::chain_hash;
    use crate::cache::engine::CacheConfig;
    use crate::cache::tier::Tier;
    use crate::cluster::router::{registry, ReplicaView};
    use crate::util::proptest::{check, forall};
    use crate::util::rng::Rng;

    const CHUNK_BYTES: u64 = 100;

    fn chain_of(tag: u32, n: usize) -> Vec<ChunkKey> {
        let mut keys = Vec::new();
        let mut parent = ChunkKey::ROOT;
        for i in 0..n {
            let k = chain_hash(parent, &[tag, i as u32]);
            keys.push(k);
            parent = k;
        }
        keys
    }

    fn insert_chain(e: &mut CacheEngine, chain: &[ChunkKey], tier: Tier) {
        let mut parent = None;
        for k in chain {
            match e.insert(parent, *k, CHUNK_BYTES, tier) {
                Some(id) => parent = Some(id),
                None => break,
            }
        }
    }

    fn tracked_engine(dram: u64, ssd: u64) -> CacheEngine {
        let mut e = CacheEngine::new(CacheConfig {
            chunk_tokens: 4,
            gpu_capacity: 0,
            dram_capacity: dram,
            ssd_capacity: ssd,
            policy: "lookahead-lru".into(),
        });
        e.track_events = true;
        e
    }

    #[test]
    fn holder_masks_follow_events() {
        let mut d = PrefixDirectory::new(3);
        let c = chain_of(1, 2);
        d.apply(0, &CacheEvent::Resident(c[0]));
        d.apply(2, &CacheEvent::Resident(c[0]));
        d.apply(2, &CacheEvent::Resident(c[1]));
        assert_eq!(d.holders(c[0]), 0b101);
        assert_eq!(d.holders(c[1]), 0b100);
        assert_eq!(d.len(), 2);
        d.apply(0, &CacheEvent::Gone(c[0]));
        assert_eq!(d.holders(c[0]), 0b100);
        // dropping the last holder removes the entry entirely
        d.apply(2, &CacheEvent::Gone(c[0]));
        assert_eq!(d.holders(c[0]), 0);
        assert_eq!(d.len(), 1);
        // Gone for a replica that never held it is a no-op
        d.apply(1, &CacheEvent::Gone(c[1]));
        assert_eq!(d.holders(c[1]), 0b100);
    }

    #[test]
    fn matched_prefix_stops_at_first_gap() {
        let mut d = PrefixDirectory::new(2);
        let c = chain_of(7, 4);
        for k in [c[0], c[1], c[3]] {
            d.apply(0, &CacheEvent::Resident(k));
        }
        // replica 0 holds chunks 0,1,3 — the gap at 2 ends the prefix
        assert_eq!(d.matched_prefix_one(0, &c), 2);
        assert_eq!(d.matched_prefix_one(1, &c), 0);
        assert_eq!(d.matched_prefix_all(&c), vec![2, 0]);
        // full-chain holder reports the whole length
        for k in &c {
            d.apply(1, &CacheEvent::Resident(*k));
        }
        assert_eq!(d.matched_prefix_all(&c), vec![2, 4]);
        assert_eq!(d.matched_prefix_one(1, &c), 4);
    }

    #[test]
    fn matched_prefix_all_agrees_with_per_replica_probes() {
        let mut rng = Rng::new(0xD1A);
        let mut d = PrefixDirectory::new(5);
        let chains: Vec<Vec<ChunkKey>> = (0..8).map(|t| chain_of(t, 1 + t as usize % 5)).collect();
        for _ in 0..400 {
            let chain = &chains[rng.below(8) as usize];
            let r = rng.below(5) as usize;
            let k = chain[rng.below(chain.len() as u64) as usize];
            if rng.below(3) == 0 {
                d.apply(r, &CacheEvent::Gone(k));
            } else {
                d.apply(r, &CacheEvent::Resident(k));
            }
            let probe = &chains[rng.below(8) as usize];
            let all = d.matched_prefix_all(probe);
            for rep in 0..5 {
                assert_eq!(all[rep], d.matched_prefix_one(rep, probe));
            }
        }
    }

    #[test]
    fn clear_replica_wipes_its_bits_and_consistency_exempts_the_dead() {
        let mut d = PrefixDirectory::new(2);
        let c = chain_of(3, 3);
        let mut engines: Vec<CacheEngine> = (0..2).map(|_| tracked_engine(800, 800)).collect();
        insert_chain(&mut engines[0], &c, Tier::Dram);
        insert_chain(&mut engines[1], &c[..1], Tier::Ssd);
        for (i, e) in engines.iter_mut().enumerate() {
            for ev in e.take_events() {
                d.apply(i, &ev);
            }
        }
        assert_eq!(d.holders(c[0]), 0b11);
        // replica 0 dies: its bits vanish, solely-held entries go
        d.clear_replica(0);
        assert_eq!(d.holders(c[0]), 0b10);
        assert_eq!(d.holders(c[1]), 0);
        assert_eq!(d.len(), 1);
        assert_eq!(d.matched_prefix_all(&c), vec![0, 1]);
        // the full check now fails (replica 0's tree still has chunks)
        let refs: Vec<&CacheEngine> = engines.iter().collect();
        assert!(d.check_consistent(&refs).is_err());
        // ...but the alive-masked check exempts the dead replica
        d.check_consistent_alive(&refs, &[false, true]).unwrap();
    }

    #[test]
    fn max_width_directory_works() {
        let mut d = PrefixDirectory::new(MAX_REPLICAS);
        let c = chain_of(1, 1);
        d.apply(63, &CacheEvent::Resident(c[0]));
        assert_eq!(d.matched_prefix_one(63, &c), 1);
        let all = d.matched_prefix_all(&c);
        assert_eq!(all[63], 1);
        assert_eq!(all[0], 0);
    }

    /// Property (satellite 3): the directory stays consistent with the
    /// replica-local trees under random insert / evict / demote /
    /// promote / route interleavings, with events drained after every
    /// operation — exactly the cadence `Replica::step` guarantees.
    #[test]
    fn prop_directory_tracks_replica_trees() {
        forall(
            0xD1EC7,
            40,
            |rng: &mut Rng| {
                let n = 5 + rng.below(60) as usize;
                (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
            },
            |ops| {
                const N: usize = 3;
                let mut dir = PrefixDirectory::new(N);
                // small tiers so eviction pressure actually fires
                let mut engines: Vec<CacheEngine> =
                    (0..N).map(|_| tracked_engine(400, 700)).collect();
                let mut router = registry::parse("affinity-balanced").unwrap();
                let chains: Vec<Vec<ChunkKey>> =
                    (0..6).map(|t| chain_of(t, 1 + (t as usize % 4))).collect();
                for &op in ops {
                    let r = (op % N as u64) as usize;
                    let chain = &chains[((op >> 4) % 6) as usize];
                    match (op >> 8) % 7 {
                        0 => insert_chain(&mut engines[r], chain, Tier::Dram),
                        1 => insert_chain(&mut engines[r], chain, Tier::Ssd),
                        2 => {
                            engines[r].evict_one(Tier::Dram);
                        }
                        3 => {
                            engines[r].lookup(chain);
                        }
                        4 => {
                            for id in engines[r].prefetch_targets(chain) {
                                engines[r].promote(id, Tier::Dram);
                            }
                        }
                        5 => {
                            // demote the chain's LAST chunk — always a
                            // leaf (chains are tag-disjoint), so the
                            // leaf-only removal rule holds
                            let last = *chain.last().unwrap();
                            if let Some(id) = engines[r].tree.get(last) {
                                engines[r].demote(id, Tier::Dram);
                            }
                        }
                        _ => {
                            let views: Vec<ReplicaView> = (0..N)
                                .map(|id| ReplicaView {
                                    id,
                                    waiting: ((op >> 16) % 7) as usize,
                                    decoding: ((op >> 24) % 3) as usize,
                                    clock: 0.0,
                                })
                                .collect();
                            let t = router.route(chain, &views, &dir);
                            if t >= N {
                                return Err(format!("router returned replica {t} of {N}"));
                            }
                        }
                    }
                    for (i, e) in engines.iter_mut().enumerate() {
                        for ev in e.take_events() {
                            dir.apply(i, &ev);
                        }
                    }
                    let refs: Vec<&CacheEngine> = engines.iter().collect();
                    if let Err(m) = dir.check_consistent(&refs) {
                        return Err(m);
                    }
                }
                check(true, "")
            },
        );
    }
}
