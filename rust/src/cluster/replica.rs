//! One serving replica: a full [`EngineCore`] (cache + scheduler queue
//! + prefetcher + metrics) behind a handle that republishes cache
//! residency events into the global [`PrefixDirectory`] after every
//! step — the callback feed the directory-consistency invariants in
//! the [`crate::cluster`] guide rely on.

use crate::cluster::directory::PrefixDirectory;
use crate::cluster::router::ReplicaView;
use crate::config::ExperimentConfig;
use crate::serve::engine::{EngineCore, RunOutcome};
use crate::serve::request::Request;
use crate::serve::system::SystemSpec;

/// A replica id plus its engine. The id doubles as the replica's bit
/// position in the directory's holder masks.
pub struct Replica {
    pub id: usize,
    pub core: EngineCore,
}

impl Replica {
    /// Build replica `id` for `cfg` × `spec`, with residency-event
    /// tracking enabled so the directory can mirror its cache.
    pub fn new(
        id: usize,
        cfg: &ExperimentConfig,
        spec: &SystemSpec,
        mean_input_tokens: f64,
    ) -> Replica {
        let mut core = EngineCore::new(cfg, spec, mean_input_tokens);
        core.cache.track_events = true;
        Replica { id, core }
    }

    /// Admit a routed request.
    pub fn enqueue(&mut self, req: Request) {
        self.core.enqueue(req);
    }

    pub fn is_idle(&self) -> bool {
        self.core.is_idle()
    }

    /// The replica's virtual clock (seconds).
    pub fn clock(&self) -> f64 {
        self.core.clock
    }

    /// The routing-visible snapshot of this replica.
    pub fn view(&self) -> ReplicaView {
        ReplicaView {
            id: self.id,
            waiting: self.core.waiting.len(),
            decoding: self.core.decoding_len(),
            clock: self.core.clock,
        }
    }

    /// One engine pass, then publish the residency transitions it
    /// caused — the directory is never more than one step stale.
    pub fn step(&mut self, directory: &mut PrefixDirectory) {
        self.core.step();
        self.publish(directory);
    }

    /// Drain the cache's event feed into the directory.
    pub fn publish(&mut self, directory: &mut PrefixDirectory) {
        for ev in self.core.cache.take_events() {
            directory.apply(self.id, &ev);
        }
    }

    /// Fail this replica: evacuate its open requests (reset for
    /// re-routing) and clear its holder bits from the directory so no
    /// future placement counts on its cache. The replica itself stops
    /// being stepped by the simulator — its cache dies with it.
    pub fn fail(&mut self, directory: &mut PrefixDirectory) -> Vec<Request> {
        // flush events from its last step first, then wipe — otherwise
        // a queued Resident event could resurrect a cleared bit
        self.publish(directory);
        directory.clear_replica(self.id);
        self.core.evacuate()
    }

    /// Finalize into the same outcome struct single-engine runs emit.
    pub fn into_outcome(self) -> RunOutcome {
        self.core.into_outcome()
    }
}
