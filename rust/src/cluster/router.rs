//! Pluggable request-routing policies for the replica fleet.
//!
//! Same open-registry shape as `cache::policy` / `cache::prefetch`:
//! a [`RoutingPolicy`] trait, built-in implementations, and a
//! [`registry`] that resolves config/CLI names (`cluster.router`,
//! `--router`). The module guide in [`crate::cluster`] documents the
//! routing contract and a worked custom-policy example.

use crate::cache::chunk::ChunkKey;
use crate::cluster::directory::PrefixDirectory;
use std::cmp::Reverse;

/// What a router is allowed to observe about one replica: queue depths
/// and its virtual clock — never the replica's prefix tree. The slice
/// handed to [`RoutingPolicy::route`] is in id order, but ids may be
/// *sparse*: a failed replica is excluded from the views, so
/// `views[i].id == i` only holds while the whole fleet is healthy.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaView {
    pub id: usize,
    /// Requests queued but not yet prefilled.
    pub waiting: usize,
    /// Requests in their decode phase.
    pub decoding: usize,
    /// The replica's virtual clock (seconds).
    pub clock: f64,
}

impl ReplicaView {
    /// Outstanding work: queued + decoding requests.
    pub fn load(&self) -> usize {
        self.waiting + self.decoding
    }
}

/// A routing decision: pick the replica index for a request given its
/// chunk chain, the fleet's queue states, and the global prefix
/// directory. `&mut self` so policies may keep internal state (e.g.
/// round-robin's cursor); decisions must stay deterministic.
pub trait RoutingPolicy: std::fmt::Debug + Send {
    /// Registry name (diagnostics, reports, bench labels).
    fn name(&self) -> &'static str;

    /// A **position** into `views`, in `0..views.len()` (`views` is
    /// never empty; out-of-range values are clamped by the caller, not
    /// trusted). The caller resolves the position to `views[pos].id` —
    /// policies must not return a replica id directly, because failed
    /// replicas are excluded and ids can be sparse.
    fn route(
        &mut self,
        chain: &[ChunkKey],
        views: &[ReplicaView],
        directory: &PrefixDirectory,
    ) -> usize;
}

/// Cache-oblivious baseline: cycle through the replicas in id order.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(
        &mut self,
        _chain: &[ChunkKey],
        views: &[ReplicaView],
        _dir: &PrefixDirectory,
    ) -> usize {
        let r = self.next % views.len();
        self.next = (self.next + 1) % views.len();
        r
    }
}

/// Pick the replica with the fewest outstanding requests (ties go to
/// the lowest id). Balances load, ignores cache placement.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl RoutingPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        _chain: &[ChunkKey],
        views: &[ReplicaView],
        _dir: &PrefixDirectory,
    ) -> usize {
        views
            .iter()
            .enumerate()
            .min_by_key(|(_, v)| (v.load(), v.id))
            .expect("views is never empty")
            .0
    }
}

/// Maximize the matched prefix: route to the replica the directory says
/// holds the longest resident prefix of the chain; break prefix ties by
/// load, then id. Pure affinity — a hot prefix can pile all its repeats
/// onto one replica.
#[derive(Debug, Default)]
pub struct PrefixAffinity;

impl RoutingPolicy for PrefixAffinity {
    fn name(&self) -> &'static str {
        "prefix-affinity"
    }

    fn route(&mut self, chain: &[ChunkKey], views: &[ReplicaView], dir: &PrefixDirectory) -> usize {
        let matched = dir.matched_prefix_all(chain);
        views
            .iter()
            .enumerate()
            .min_by_key(|(_, v)| (Reverse(matched[v.id]), v.load(), v.id))
            .expect("views is never empty")
            .0
    }
}

/// Affinity tempered by load: score each replica
/// `matched_chunks − alpha × load` and take the max (ties: lowest load,
/// then lowest id). `alpha` is the exchange rate — how many queued
/// requests one matched chunk is worth; `alpha = 0` degenerates to
/// [`PrefixAffinity`], large `alpha` to [`LeastLoaded`].
#[derive(Debug)]
pub struct AffinityBalanced {
    pub alpha: f64,
}

impl AffinityBalanced {
    /// Half a request per matched chunk: a typical few-chunk prefix
    /// outweighs small queue gaps, but a deep backlog still diverts.
    pub const DEFAULT_ALPHA: f64 = 0.5;
}

impl Default for AffinityBalanced {
    fn default() -> Self {
        AffinityBalanced {
            alpha: Self::DEFAULT_ALPHA,
        }
    }
}

impl RoutingPolicy for AffinityBalanced {
    fn name(&self) -> &'static str {
        "affinity-balanced"
    }

    fn route(&mut self, chain: &[ChunkKey], views: &[ReplicaView], dir: &PrefixDirectory) -> usize {
        let matched = dir.matched_prefix_all(chain);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        let mut best_load = usize::MAX;
        for (pos, v) in views.iter().enumerate() {
            let score = matched[v.id] as f64 - self.alpha * v.load() as f64;
            if score > best_score || (score == best_score && v.load() < best_load) {
                best = pos;
                best_score = score;
                best_load = v.load();
            }
        }
        best
    }
}

/// Name → policy resolution for `cluster.router` / `--router`.
pub mod registry {
    use super::*;

    /// Registered policy names, sweep order.
    pub const NAMES: [&str; 4] = [
        "round-robin",
        "least-loaded",
        "prefix-affinity",
        "affinity-balanced",
    ];

    /// `", "`-joined [`NAMES`] for error messages.
    pub fn names_joined() -> String {
        NAMES.join(", ")
    }

    /// Resolve a policy name (case-insensitive). `affinity-balanced`
    /// accepts an `:alpha` suffix, e.g. `affinity-balanced:0.25`.
    pub fn parse(name: &str) -> Option<Box<dyn RoutingPolicy>> {
        let name = name.to_ascii_lowercase();
        match name.as_str() {
            "round-robin" | "rr" => return Some(Box::new(RoundRobin::default())),
            "least-loaded" => return Some(Box::new(LeastLoaded)),
            "prefix-affinity" | "affinity" => return Some(Box::new(PrefixAffinity)),
            "affinity-balanced" => return Some(Box::new(AffinityBalanced::default())),
            _ => {}
        }
        if let Some(alpha) = name.strip_prefix("affinity-balanced:") {
            let alpha: f64 = alpha.parse().ok()?;
            if !alpha.is_finite() || alpha < 0.0 {
                return None;
            }
            return Some(Box::new(AffinityBalanced { alpha }));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::chain_hash;
    use crate::cache::engine::CacheEvent;

    fn chain_of(tag: u32, n: usize) -> Vec<ChunkKey> {
        let mut keys = Vec::new();
        let mut parent = ChunkKey::ROOT;
        for i in 0..n {
            let k = chain_hash(parent, &[tag, i as u32]);
            keys.push(k);
            parent = k;
        }
        keys
    }

    fn views(loads: &[(usize, usize)]) -> Vec<ReplicaView> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &(waiting, decoding))| ReplicaView {
                id,
                waiting,
                decoding,
                clock: 0.0,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::default();
        let d = PrefixDirectory::new(3);
        let v = views(&[(0, 0), (9, 9), (0, 0)]);
        let c = chain_of(1, 2);
        let picks: Vec<usize> = (0..5).map(|_| rr.route(&c, &v, &d)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_loaded_prefers_emptiest_then_lowest_id() {
        let mut ll = LeastLoaded;
        let d = PrefixDirectory::new(3);
        let c = chain_of(1, 2);
        assert_eq!(ll.route(&c, &views(&[(4, 0), (1, 1), (0, 1)]), &d), 2);
        // tie on load=1 between ids 1 and 2 → lowest id
        assert_eq!(ll.route(&c, &views(&[(3, 0), (1, 0), (0, 1)]), &d), 1);
    }

    #[test]
    fn prefix_affinity_follows_the_directory() {
        let mut pa = PrefixAffinity;
        let mut d = PrefixDirectory::new(3);
        let c = chain_of(7, 3);
        // nobody holds anything → tie broken by load, then id
        assert_eq!(pa.route(&c, &views(&[(1, 0), (0, 0), (2, 0)]), &d), 1);
        // replica 2 holds a 2-chunk prefix → wins despite higher load
        d.apply(2, &CacheEvent::Resident(c[0]));
        d.apply(2, &CacheEvent::Resident(c[1]));
        assert_eq!(pa.route(&c, &views(&[(0, 0), (0, 0), (5, 0)]), &d), 2);
    }

    #[test]
    fn affinity_balanced_trades_prefix_for_load() {
        let mut d = PrefixDirectory::new(2);
        let c = chain_of(9, 4);
        for k in &c {
            d.apply(0, &CacheEvent::Resident(*k));
        }
        // 4 matched chunks at alpha=0.5 are worth 8 queued requests:
        // a 6-deep backlog still routes to the holder...
        let mut ab = AffinityBalanced::default();
        assert_eq!(ab.route(&c, &views(&[(6, 0), (0, 0)]), &d), 0);
        // ...a 10-deep backlog diverts to the idle replica
        assert_eq!(ab.route(&c, &views(&[(10, 0), (0, 0)]), &d), 1);
        // alpha = 0 is pure affinity, any backlog tolerated
        let mut pure = AffinityBalanced { alpha: 0.0 };
        assert_eq!(pure.route(&c, &views(&[(50, 0), (0, 0)]), &d), 0);
    }

    #[test]
    fn routers_return_positions_under_sparse_views() {
        // failover hands routers a views slice with a replica missing;
        // every policy must answer with a POSITION into that slice
        let mut d = PrefixDirectory::new(3);
        let c = chain_of(5, 2);
        // replica 2 holds the whole chain; replica 1 is dead/excluded
        d.apply(2, &CacheEvent::Resident(c[0]));
        d.apply(2, &CacheEvent::Resident(c[1]));
        let sparse = vec![
            ReplicaView { id: 0, waiting: 0, decoding: 0, clock: 0.0 },
            ReplicaView { id: 2, waiting: 9, decoding: 0, clock: 0.0 },
        ];
        // prefix-affinity picks holder id 2 — at position 1
        let mut pa = PrefixAffinity;
        assert_eq!(pa.route(&c, &sparse, &d), 1);
        // least-loaded picks idle id 0 — at position 0
        let mut ll = LeastLoaded;
        assert_eq!(ll.route(&c, &sparse, &d), 0);
        // affinity-balanced at huge alpha degenerates to least-loaded
        let mut ab = AffinityBalanced { alpha: 100.0 };
        assert_eq!(ab.route(&c, &sparse, &d), 0);
        // round-robin cycles positions, never touching absent ids
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..4).map(|_| rr.route(&c, &sparse, &d)).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn registry_parses_names_aliases_and_alpha() {
        for name in registry::NAMES {
            let p = registry::parse(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert_eq!(registry::parse("RR").unwrap().name(), "round-robin");
        assert_eq!(registry::parse("Affinity").unwrap().name(), "prefix-affinity");
        assert_eq!(registry::parse("affinity-balanced:0.25").unwrap().name(), "affinity-balanced");
        assert!(registry::parse("affinity-balanced:-1").is_none());
        assert!(registry::parse("affinity-balanced:NaN").is_none());
        assert!(registry::parse("random").is_none());
        assert!(registry::names_joined().contains("prefix-affinity"));
    }
}
