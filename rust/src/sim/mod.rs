//! Discrete-event simulation core and the layer-wise overlap pipeline.
//!
//! * [`events`] — virtual clock, event queue, FIFO job-shop replayer.
//! * [`pipeline`] — Fig 8's three-stream layer-wise overlapping, with
//!   analytic makespans validated against the DES replay.

pub mod events;
pub mod pipeline;
