//! Discrete-event simulation core: a virtual clock, an event queue, and
//! a dependency-graph job-shop used to replay transfer/compute pipelines
//! on FIFO resources. `sim::pipeline` proves its analytic makespan
//! formulas against this replayer (the two must agree exactly).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// f64 wrapper with total order (no NaNs admitted) for the heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Time(pub f64);

impl Eq for Time {}
impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Time {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

/// A min-heap of (time, tie-break seq, payload).
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    now: f64,
}

#[derive(Debug)]
struct Entry<T> {
    time: Time,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first;
        // ties broken by insertion order (FIFO determinism).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `t` (must be >= now).
    pub fn schedule(&mut self, t: f64, payload: T) {
        debug_assert!(t >= self.now - 1e-12, "scheduling into the past");
        self.heap.push(Entry {
            time: Time(t),
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock.
    pub fn next(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| {
            self.now = e.time.0;
            (e.time.0, e.payload)
        })
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time.0)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// A job in the dependency-graph job shop: runs on one FIFO resource,
/// starts when all dependencies finished AND the resource is free AND
/// its release time has passed.
#[derive(Clone, Debug)]
pub struct Job {
    pub resource: usize,
    pub duration: f64,
    pub deps: Vec<usize>,
    pub release: f64,
}

impl Job {
    pub fn new(resource: usize, duration: f64, deps: Vec<usize>) -> Job {
        Job {
            resource,
            duration,
            deps,
            release: 0.0,
        }
    }
}

/// Simulate jobs on FIFO resources. Jobs submitted to the same resource
/// execute in submission (index) order — this models CUDA streams /
/// DMA queues, where ops issue in order. Returns per-job finish times.
///
/// Panics on cyclic dependencies.
pub fn run_job_shop(jobs: &[Job], n_resources: usize) -> Vec<f64> {
    let mut finish = vec![f64::NAN; jobs.len()];
    let mut resource_free = vec![0.0f64; n_resources];
    // FIFO per resource: process jobs in index order per resource, but a
    // job's start also waits on deps, which may belong to later-indexed
    // jobs on other resources — iterate until fixpoint in topological
    // fashion. Since streams are FIFO, within a resource order is fixed;
    // across resources we resolve by repeatedly scanning for the next
    // runnable job per resource.
    let mut next_idx: Vec<usize> = vec![0; n_resources];
    let mut per_resource: Vec<Vec<usize>> = vec![Vec::new(); n_resources];
    for (i, j) in jobs.iter().enumerate() {
        assert!(j.resource < n_resources, "bad resource id");
        per_resource[j.resource].push(i);
    }
    let total = jobs.len();
    let mut done = 0;
    while done < total {
        let mut progressed = false;
        for r in 0..n_resources {
            while next_idx[r] < per_resource[r].len() {
                let ji = per_resource[r][next_idx[r]];
                let job = &jobs[ji];
                // all deps finished?
                if job.deps.iter().any(|d| finish[*d].is_nan()) {
                    break; // FIFO head blocked; resource stalls
                }
                let dep_ready = job
                    .deps
                    .iter()
                    .map(|d| finish[*d])
                    .fold(job.release, f64::max);
                let start = dep_ready.max(resource_free[r]);
                finish[ji] = start + job.duration;
                resource_free[r] = finish[ji];
                next_idx[r] += 1;
                done += 1;
                progressed = true;
            }
        }
        assert!(
            progressed || done == total,
            "deadlock: cyclic dependency or dep on never-scheduled job"
        );
    }
    finish
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "b");
        q.schedule(1.0, "a");
        q.schedule(2.0, "c"); // same time as b, inserted later
        assert_eq!(q.next(), Some((1.0, "a")));
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.next(), Some((2.0, "b")));
        assert_eq!(q.next(), Some((2.0, "c")));
        assert!(q.next().is_none());
    }

    #[test]
    fn job_shop_chain() {
        // serial chain on one resource
        let jobs = vec![
            Job::new(0, 1.0, vec![]),
            Job::new(0, 2.0, vec![0]),
            Job::new(0, 3.0, vec![1]),
        ];
        let f = run_job_shop(&jobs, 1);
        assert_eq!(f, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn job_shop_parallel_resources() {
        // two independent chains on two resources
        let jobs = vec![
            Job::new(0, 1.0, vec![]),
            Job::new(1, 1.5, vec![]),
            Job::new(0, 1.0, vec![0]),
            Job::new(1, 1.5, vec![1]),
        ];
        let f = run_job_shop(&jobs, 2);
        assert_eq!(f, vec![1.0, 1.5, 2.0, 3.0]);
    }

    #[test]
    fn job_shop_cross_resource_dependency() {
        // compute (r1) waits for upload (r0); download (r2) waits compute
        let jobs = vec![
            Job::new(0, 1.0, vec![]),  // upload
            Job::new(1, 2.0, vec![0]), // compute after upload
            Job::new(2, 0.5, vec![1]), // download after compute
        ];
        let f = run_job_shop(&jobs, 3);
        assert_eq!(f, vec![1.0, 3.0, 3.5]);
    }

    #[test]
    fn job_shop_fifo_blocks_head_of_line() {
        // r0: job0 (dep on job1@r1, long) then job2. FIFO means job2
        // cannot overtake job0 even though it has no deps.
        let jobs = vec![
            Job::new(0, 1.0, vec![1]),
            Job::new(1, 5.0, vec![]),
            Job::new(0, 1.0, vec![]),
        ];
        let f = run_job_shop(&jobs, 2);
        assert_eq!(f, vec![6.0, 5.0, 7.0]);
    }

    #[test]
    fn release_time_respected() {
        let mut j = Job::new(0, 1.0, vec![]);
        j.release = 10.0;
        let f = run_job_shop(&[j], 1);
        assert_eq!(f, vec![11.0]);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn cyclic_deps_panic() {
        let jobs = vec![Job::new(0, 1.0, vec![1]), Job::new(1, 1.0, vec![0])];
        run_job_shop(&jobs, 2);
    }
}
