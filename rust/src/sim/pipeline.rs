//! Layer-wise overlapping (paper §4.3, Fig 8): pipelining KV-cache
//! upload, GPU computation, and KV offload across three FIFO lanes
//! ("CUDA streams"), at transformer-layer granularity.
//!
//! Dependency structure per layer l:
//!   compute[l]  waits on  upload[l]   (needs that layer's reused KV)
//!   download[l] waits on  compute[l]  (offloads that layer's new KV)
//! and each lane is FIFO. The analytic makespan below is validated
//! against the `sim::events` job-shop replay in tests — they must agree
//! to float precision.

use crate::sim::events::{run_job_shop, Job};

/// Which transfers overlap with compute (Fig 18's ablation arms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// Fully synchronous: all uploads, then all compute, then all
    /// downloads (the Sync-Swap baseline of Fig 1).
    Sync,
    /// Only uploads overlap with compute; downloads happen at the end.
    OnlyUp,
    /// Uploads happen up front; downloads overlap with compute.
    OnlyDown,
    /// Full three-stream overlap (PCR).
    UpDown,
}

impl OverlapMode {
    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s {
            "sync" => Some(OverlapMode::Sync),
            "only-up" | "up" => Some(OverlapMode::OnlyUp),
            "only-down" | "down" => Some(OverlapMode::OnlyDown),
            "up-down" | "updown" | "full" => Some(OverlapMode::UpDown),
            _ => None,
        }
    }
}

/// Per-layer timings of one forward pass.
#[derive(Clone, Debug)]
pub struct LayerTimings {
    /// Upload time of each layer's reused KV (H2D).
    pub up: Vec<f64>,
    /// Compute time of each layer.
    pub compute: Vec<f64>,
    /// Download time of each layer's newly generated KV (D2H).
    pub down: Vec<f64>,
    /// Per-layer pipeline synchronization overhead (event record/wait) —
    /// the cost that makes full overlap non-free for small KV (Fig 18's
    /// Qwen anomaly where only-down beats up-down).
    pub sync_overhead: f64,
}

impl LayerTimings {
    /// Uniform timings across `n` layers.
    pub fn uniform(n: usize, up: f64, compute: f64, down: f64, sync_overhead: f64) -> Self {
        LayerTimings {
            up: vec![up / n as f64; n],
            compute: vec![compute / n as f64; n],
            down: vec![down / n as f64; n],
            sync_overhead,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.compute.len()
    }

    fn total_up(&self) -> f64 {
        self.up.iter().sum()
    }
    fn total_compute(&self) -> f64 {
        self.compute.iter().sum()
    }
    fn total_down(&self) -> f64 {
        self.down.iter().sum()
    }
}

/// Analytic makespan of the layer-wise pipeline under `mode`.
///
/// Recurrences (lane cursors u, c, d):
///   u[l] = u[l-1] + up[l]
///   c[l] = max(c[l-1], u[l]) + compute[l] (+sync if overlapping up)
///   d[l] = max(d[l-1], c[l]) + down[l]
pub fn makespan(t: &LayerTimings, mode: OverlapMode) -> f64 {
    let n = t.n_layers();
    assert_eq!(t.up.len(), n);
    assert_eq!(t.down.len(), n);
    match mode {
        OverlapMode::Sync => t.total_up() + t.total_compute() + t.total_down(),
        OverlapMode::OnlyUp => {
            let mut u = 0.0f64;
            let mut c = 0.0f64;
            for l in 0..n {
                u += t.up[l];
                c = c.max(u) + t.compute[l] + t.sync_overhead;
            }
            c + t.total_down()
        }
        OverlapMode::OnlyDown => {
            let up_front = t.total_up();
            let mut c = up_front;
            let mut d = up_front;
            for l in 0..n {
                c += t.compute[l] + t.sync_overhead;
                d = d.max(c) + t.down[l];
            }
            d
        }
        OverlapMode::UpDown => {
            let mut u = 0.0f64;
            let mut c = 0.0f64;
            let mut d = 0.0f64;
            for l in 0..n {
                u += t.up[l];
                c = c.max(u) + t.compute[l] + 2.0 * t.sync_overhead;
                d = d.max(c) + t.down[l];
            }
            d
        }
    }
}

/// Replay the same pipeline on the discrete-event job shop (3 FIFO
/// resources: 0 = H2D stream, 1 = compute stream, 2 = D2H stream).
/// Used by tests to validate `makespan`.
pub fn makespan_des(t: &LayerTimings, mode: OverlapMode) -> f64 {
    let n = t.n_layers();
    let mut jobs: Vec<Job> = Vec::with_capacity(3 * n);
    match mode {
        OverlapMode::Sync => {
            // one serial chain on a single resource
            let mut prev: Option<usize> = None;
            for phase in 0..3 {
                for l in 0..n {
                    let dur = match phase {
                        0 => t.up[l],
                        1 => t.compute[l],
                        _ => t.down[l],
                    };
                    let deps = prev.map(|p| vec![p]).unwrap_or_default();
                    jobs.push(Job::new(0, dur, deps));
                    prev = Some(jobs.len() - 1);
                }
            }
            let f = run_job_shop(&jobs, 1);
            f.last().copied().unwrap_or(0.0)
        }
        OverlapMode::OnlyUp => {
            let mut up_ids = Vec::new();
            for l in 0..n {
                jobs.push(Job::new(0, t.up[l], vec![]));
                up_ids.push(jobs.len() - 1);
            }
            let mut last_c = None;
            for l in 0..n {
                let mut deps = vec![up_ids[l]];
                if let Some(p) = last_c {
                    deps.push(p);
                }
                jobs.push(Job::new(1, t.compute[l] + t.sync_overhead, deps));
                last_c = Some(jobs.len() - 1);
            }
            // downloads serialized after the last compute
            let mut prev = last_c.unwrap();
            for l in 0..n {
                jobs.push(Job::new(2, t.down[l], vec![prev]));
                prev = jobs.len() - 1;
            }
            let f = run_job_shop(&jobs, 3);
            f.last().copied().unwrap_or(0.0)
        }
        OverlapMode::OnlyDown => {
            // one big upfront upload
            jobs.push(Job::new(0, t.total_up(), vec![]));
            let up_id = 0;
            let mut c_ids = Vec::new();
            let mut last_c = None;
            for l in 0..n {
                let mut deps = vec![up_id];
                if let Some(p) = last_c {
                    deps = vec![p];
                }
                jobs.push(Job::new(1, t.compute[l] + t.sync_overhead, deps));
                last_c = Some(jobs.len() - 1);
                c_ids.push(jobs.len() - 1);
            }
            for l in 0..n {
                jobs.push(Job::new(2, t.down[l], vec![c_ids[l]]));
            }
            let f = run_job_shop(&jobs, 3);
            f.iter().copied().fold(0.0, f64::max)
        }
        OverlapMode::UpDown => {
            let mut up_ids = Vec::new();
            for l in 0..n {
                jobs.push(Job::new(0, t.up[l], vec![]));
                up_ids.push(jobs.len() - 1);
            }
            let mut c_ids = Vec::new();
            let mut last_c = None;
            for l in 0..n {
                let mut deps = vec![up_ids[l]];
                if let Some(p) = last_c {
                    deps.push(p);
                }
                jobs.push(Job::new(1, t.compute[l] + 2.0 * t.sync_overhead, deps));
                last_c = Some(jobs.len() - 1);
                c_ids.push(jobs.len() - 1);
            }
            for l in 0..n {
                jobs.push(Job::new(2, t.down[l], vec![c_ids[l]]));
            }
            let f = run_job_shop(&jobs, 3);
            f.iter().copied().fold(0.0, f64::max)
        }
    }
}

/// The paper's §4.3 claim: with full overlap and per-layer transfer
/// smaller than per-layer compute, effective transfer overhead shrinks
/// from C1 to ~C1/n. Returns (sync_total, overlap_total, reduction).
pub fn overlap_benefit(t: &LayerTimings) -> (f64, f64, f64) {
    let sync = makespan(t, OverlapMode::Sync);
    let ovl = makespan(t, OverlapMode::UpDown);
    (sync, ovl, sync - ovl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn analytic_matches_des_uniform() {
        let t = LayerTimings::uniform(8, 0.4, 1.6, 0.8, 0.0);
        for mode in [
            OverlapMode::Sync,
            OverlapMode::OnlyUp,
            OverlapMode::OnlyDown,
            OverlapMode::UpDown,
        ] {
            let a = makespan(&t, mode);
            let d = makespan_des(&t, mode);
            assert!(close(a, d), "{mode:?}: analytic {a} != des {d}");
        }
    }

    #[test]
    fn analytic_matches_des_random() {
        let mut rng = Rng::new(42);
        for case in 0..200 {
            let n = 1 + rng.below(40) as usize;
            let t = LayerTimings {
                up: (0..n).map(|_| rng.f64() * 0.1).collect(),
                compute: (0..n).map(|_| rng.f64() * 0.2).collect(),
                down: (0..n).map(|_| rng.f64() * 0.15).collect(),
                sync_overhead: rng.f64() * 0.001,
            };
            for mode in [
                OverlapMode::Sync,
                OverlapMode::OnlyUp,
                OverlapMode::OnlyDown,
                OverlapMode::UpDown,
            ] {
                let a = makespan(&t, mode);
                let d = makespan_des(&t, mode);
                assert!(
                    close(a, d),
                    "case {case} {mode:?}: analytic {a} != des {d} (n={n})"
                );
            }
        }
    }

    #[test]
    fn full_overlap_reduces_overhead_to_one_layer() {
        // compute-dominated: per-layer transfer < per-layer compute.
        // Effective overhead ≈ first-layer upload + last-layer download.
        let n = 32;
        let t = LayerTimings::uniform(n, 0.32, 3.2, 0.64, 0.0);
        let total_compute: f64 = t.compute.iter().sum();
        let ms = makespan(&t, OverlapMode::UpDown);
        let overhead = ms - total_compute;
        let one_layer = t.up[0] + t.down[0];
        assert!(close(overhead, one_layer), "overhead={overhead} expect={one_layer}");
    }

    #[test]
    fn overlap_never_worse_than_sync_without_sync_overhead() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let n = 1 + rng.below(32) as usize;
            let t = LayerTimings {
                up: (0..n).map(|_| rng.f64()).collect(),
                compute: (0..n).map(|_| rng.f64()).collect(),
                down: (0..n).map(|_| rng.f64()).collect(),
                sync_overhead: 0.0,
            };
            let sync = makespan(&t, OverlapMode::Sync);
            for mode in [OverlapMode::OnlyUp, OverlapMode::OnlyDown, OverlapMode::UpDown] {
                assert!(makespan(&t, mode) <= sync + 1e-12);
            }
        }
    }

    #[test]
    fn sync_overhead_can_make_overlap_lose() {
        // Fig 18 Qwen2.5-7B: tiny KV + per-layer sync cost => only-down
        // can beat up-down.
        let t = LayerTimings::uniform(32, 0.001, 0.5, 0.02, 0.002);
        let only_down = makespan(&t, OverlapMode::OnlyDown);
        let up_down = makespan(&t, OverlapMode::UpDown);
        assert!(only_down < up_down);
    }

    #[test]
    fn transfer_bound_pipeline_degrades_gracefully() {
        // transfer-dominated: pipeline is bound by the H2D lane.
        let t = LayerTimings::uniform(16, 4.0, 0.8, 0.4, 0.0);
        let ms = makespan(&t, OverlapMode::UpDown);
        // lower bound: total upload + one compute + one download
        let lb = 4.0 + t.compute[0] + t.down[0];
        assert!(ms >= lb - 1e-9);
        assert!(ms < 4.0 + 0.8 + 0.4 + 1e-9);
    }

    #[test]
    fn down_only_benefit_exceeds_up_only_when_down_dominates() {
        // The paper: offloading is the bigger win because ALL new KV is
        // written back while only the matched fraction is loaded.
        let t = LayerTimings::uniform(32, 0.1, 2.0, 0.8, 0.0);
        let sync = makespan(&t, OverlapMode::Sync);
        let only_up_gain = sync - makespan(&t, OverlapMode::OnlyUp);
        let only_down_gain = sync - makespan(&t, OverlapMode::OnlyDown);
        assert!(only_down_gain > only_up_gain);
    }

    #[test]
    fn single_layer_pipeline() {
        let t = LayerTimings::uniform(1, 0.3, 1.0, 0.2, 0.0);
        assert!(close(makespan(&t, OverlapMode::UpDown), 1.5));
        assert!(close(makespan(&t, OverlapMode::Sync), 1.5));
    }

    #[test]
    fn mode_parse() {
        assert_eq!(OverlapMode::parse("sync"), Some(OverlapMode::Sync));
        assert_eq!(OverlapMode::parse("up-down"), Some(OverlapMode::UpDown));
        assert_eq!(OverlapMode::parse("x"), None);
    }
}
