//! Shared experiment scenarios for the paper benches.
//!
//! The paper's full scale (Wikipedia corpus, 2000 requests of ~6.8k
//! tokens) replays here at a reduced-but-pressured scale: the tier
//! capacities are shrunk with the corpus so the GPU < DRAM < SSD
//! hierarchy stays under the same relative pressure (GPU holds a few
//! requests' KV, DRAM a fraction of the distinct working set, SSD all
//! of it). `PCR_BENCH_SCALE=full` switches to paper-scale numbers
//! (slower; used for the recorded EXPERIMENTS.md runs).

use crate::config::ExperimentConfig;
use crate::hw::spec::model_spec;
use crate::serve::workload::Workload;

/// Bench scale knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Fast CI scale (default): ~400 requests, ~3.4k-token inputs.
    Lite,
    /// Paper scale: 2000 requests, ~6.8k-token inputs.
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("PCR_BENCH_SCALE").as_deref() {
            Ok("full") => Scale::Full,
            _ => Scale::Lite,
        }
    }
}

/// The paper's workload-1 / workload-2 experiment config for `model` on
/// `platform`, with tier pressure matched to the model's KV size.
pub fn paper_config(model: &str, platform: &str, workload1: bool,
                    rate: f64, scale: Scale) -> ExperimentConfig {
    let spec = model_spec(model).expect("model");
    let kv_per_token = spec.kv_bytes_per_token();
    let (n_inputs, n_requests, mean_doc, n_docs) = match scale {
        // paper: W1 = 1000 inputs oversampled to 2000; W2 = 2000 inputs
        Scale::Full => (
            if workload1 { 1000 } else { 2000 },
            2000,
            3368,
            4000,
        ),
        Scale::Lite => (
            if workload1 { 200 } else { 400 },
            400,
            1650,
            1200,
        ),
    };
    // Mean input ≈ 2·doc + 64 query tokens.
    let mean_input = 2 * mean_doc + 64;
    // Distinct working set ≈ n_inputs · mean_input tokens (shared doc
    // prefixes reduce it; this is the upper bound used for sizing).
    let distinct_tokens = n_inputs as u64 * mean_input as u64;
    // Tier pressure mirroring §6.1: GPU ≈ 3% of the distinct set,
    // DRAM ≈ 25%, SSD ≈ 150% (holds everything).
    let gpu_bytes = distinct_tokens * kv_per_token * 3 / 100;
    let dram_bytes = distinct_tokens * kv_per_token / 4;
    let ssd_bytes = distinct_tokens * kv_per_token * 3 / 2;
    ExperimentConfig {
        model: model.into(),
        platform: platform.into(),
        n_inputs,
        n_requests,
        oversample: workload1,
        rate,
        mean_doc_tokens: mean_doc,
        n_docs,
        n_topics: 96,
        gpu_bytes,
        dram_bytes,
        ssd_bytes,
        ..Default::default()
    }
}

/// Build a workload once per (model-class, workload, rate) — reused
/// across all system variants for a fair comparison.
pub fn build_workload(cfg: &ExperimentConfig) -> Workload {
    Workload::build(cfg)
}

/// Models the paper's main grid uses, smallest-first (bench runtime).
pub fn paper_models(scale: Scale) -> Vec<&'static str> {
    match scale {
        Scale::Full => vec![
            "llama3.2-3b", "llama2-7b", "qwen2.5-7b",
            "llama3.1-8b", "llama2-13b", "qwen2.5-14b",
        ],
        Scale::Lite => vec!["llama3.1-8b", "llama2-7b", "qwen2.5-7b", "llama2-13b"],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_validate() {
        for scale in [Scale::Lite, Scale::Full] {
            for model in paper_models(scale) {
                for w1 in [true, false] {
                    let cfg = paper_config(model, "a6000", w1, 0.75, scale);
                    cfg.validate().unwrap();
                    assert!(cfg.gpu_bytes > 0 && cfg.gpu_bytes < cfg.dram_bytes);
                    assert!(cfg.dram_bytes < cfg.ssd_bytes);
                }
            }
        }
    }

    #[test]
    fn tier_pressure_scales_with_kv_size() {
        let l2 = paper_config("llama2-7b", "a6000", true, 0.5, Scale::Lite);
        let qw = paper_config("qwen2.5-7b", "a6000", true, 0.5, Scale::Lite);
        // MHA model (bigger KV/token) gets proportionally bigger tiers,
        // keeping *relative* pressure constant
        assert!(l2.dram_bytes > 4 * qw.dram_bytes);
    }
}
