//! Micro-benchmark harness (no `criterion` offline).
//!
//! `Bench::new("name").run(..)` measures a closure with warmup, adaptive
//! iteration count, and reports mean/p50/min per iteration. The paper
//! benches (`rust/benches/*.rs`, `harness = false`) use `Table` to print
//! the same rows/series the paper's tables and figures report.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.min_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Adaptive micro-benchmark runner.
pub struct Bench {
    name: String,
    min_time_s: f64,
    warmup_s: f64,
    max_iters: u64,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            min_time_s: 0.5,
            warmup_s: 0.1,
            max_iters: 10_000_000,
        }
    }

    pub fn min_time(mut self, s: f64) -> Self {
        self.min_time_s = s;
        self
    }

    pub fn max_iters(mut self, n: u64) -> Self {
        self.max_iters = n;
        self
    }

    /// Measure `f`, which should perform ONE unit of work and return a
    /// value (black-boxed to defeat dead-code elimination).
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> BenchResult {
        // warmup
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < self.warmup_s {
            black_box(f());
        }
        // measure in batches, collecting per-batch mean
        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters: u64 = 0;
        let measure_start = Instant::now();
        let mut batch: u64 = 1;
        while measure_start.elapsed().as_secs_f64() < self.min_time_s
            && total_iters < self.max_iters
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64;
            samples_ns.push(dt / batch as f64);
            total_iters += batch;
            // grow batches until each takes ~1ms
            if dt < 1_000_000.0 {
                batch = (batch * 2).min(1 << 20);
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let p50 = samples_ns[samples_ns.len() / 2];
        let min = samples_ns[0];
        BenchResult {
            name: self.name.clone(),
            iters: total_iters,
            mean_ns: mean,
            p50_ns: p50,
            min_ns: min,
        }
    }
}

/// Identity function the optimizer must assume has side effects.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len().max(8)).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        for (i, c) in cells.iter().enumerate() {
            self.widths[i] = self.widths[i].max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells);
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Print a standard bench section header so bench outputs are greppable.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = Bench::new("noop").min_time(0.05).run(|| 1 + 1);
        assert!(r.iters > 100);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns * 2.0 + 1.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn table_alignment_grows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["longer-cell".into(), "1".into()]);
        t.print(); // must not panic
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}

pub mod scenario;
