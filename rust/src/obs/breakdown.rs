//! Per-request TTFT attribution — the runnable analog of the paper's
//! Table 1 ("where does time-to-first-token go?").
//!
//! For every prefill the engine records one [`RequestBreakdown`] row
//! splitting the attempt's TTFT into the stages of the serving
//! pipeline. The split is exact by construction (see `serve::engine`):
//!
//! ```text
//!   ttft = retrieval + queue + load_stall + compute + exposed
//! ```
//!
//! * `retrieval`  — arrival → documents ready (queued).
//! * `queue`      — queued → popped by the scheduler.
//! * `load_stall` — SSD demand-load time the prefill waited on before
//!   the first layer could start (`StepBreakdown::ssd_wait`).
//! * `compute`    — pure prefill FLOP time.
//! * `exposed`    — transfer time *not* hidden behind compute
//!   (`pipeline − compute`): what the layer-wise overlap failed to
//!   absorb.
//! * `hidden`     — transfer time the overlap *did* absorb
//!   (`upload + offload − exposed`). Reported for the overlap claim
//!   but excluded from the reconciling sum — it never reached TTFT.
//!
//! Failover note: on a replica kill a re-routed request prefills
//! again, so a cluster run records one row per *prefill attempt* —
//! rows can outnumber finished requests. Each row still reconciles
//! against its own attempt's TTFT within 1e-9 (pinned by a proptest).

use crate::util::fmt_secs;
use crate::util::json::Json;

/// One prefill attempt's TTFT split (all fields virtual seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RequestBreakdown {
    /// Request id (unique per request, repeated across retry attempts).
    pub request: u64,
    pub retrieval: f64,
    pub queue: f64,
    pub load_stall: f64,
    pub compute: f64,
    pub exposed: f64,
    pub hidden: f64,
    /// The attempt's TTFT (arrival → first token of this attempt).
    pub ttft: f64,
}

impl RequestBreakdown {
    /// Sum of the attributed stages — must equal `ttft` within 1e-9.
    pub fn stage_sum(&self) -> f64 {
        self.retrieval + self.queue + self.load_stall + self.compute + self.exposed
    }

    /// Attribution residual: |stage_sum − ttft|.
    pub fn residual(&self) -> f64 {
        (self.stage_sum() - self.ttft).abs()
    }
}

/// Accumulates rows over a run; absorbable across cluster replicas.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TtftAttribution {
    pub rows: Vec<RequestBreakdown>,
}

impl TtftAttribution {
    pub fn record(&mut self, row: RequestBreakdown) {
        self.rows.push(row);
    }

    pub fn absorb(&mut self, other: &TtftAttribution) {
        self.rows.extend_from_slice(&other.rows);
    }

    /// Largest attribution residual over all rows (0 when empty) —
    /// the reconciliation invariant's test probe.
    pub fn max_residual(&self) -> f64 {
        self.rows.iter().map(|r| r.residual()).fold(0.0, f64::max)
    }

    /// Mean seconds per stage over all recorded prefills.
    pub fn summary(&self) -> BreakdownSummary {
        let n = self.rows.len();
        if n == 0 {
            return BreakdownSummary::default();
        }
        let inv = 1.0 / n as f64;
        let mut s = BreakdownSummary { n, ..BreakdownSummary::default() };
        for r in &self.rows {
            s.retrieval += r.retrieval * inv;
            s.queue += r.queue * inv;
            s.load_stall += r.load_stall * inv;
            s.compute += r.compute * inv;
            s.exposed += r.exposed * inv;
            s.hidden += r.hidden * inv;
            s.ttft += r.ttft * inv;
        }
        s
    }
}

/// Mean per-stage seconds over a run — the `Report::pretty` block and
/// the `BENCH_ttft_breakdown.json` row shape. `Copy` so `Report`
/// stays `Copy`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BreakdownSummary {
    /// Number of prefill attempts aggregated.
    pub n: usize,
    pub retrieval: f64,
    pub queue: f64,
    pub load_stall: f64,
    pub compute: f64,
    pub exposed: f64,
    pub hidden: f64,
    pub ttft: f64,
}

impl BreakdownSummary {
    pub fn any(&self) -> bool {
        self.n > 0
    }

    fn pct(&self, x: f64) -> f64 {
        if self.ttft > 0.0 {
            100.0 * x / self.ttft
        } else {
            0.0
        }
    }

    /// One-line block for `Report::pretty`: mean seconds and share of
    /// TTFT per stage, plus how much transfer the overlap hid.
    pub fn pretty(&self) -> String {
        format!(
            "ttft = retr {} ({:.0}%) + queue {} ({:.0}%) + stall {} ({:.0}%) + comp {} ({:.0}%) \
             + xfer {} ({:.0}%); overlap hid {} [{} prefills]",
            fmt_secs(self.retrieval),
            self.pct(self.retrieval),
            fmt_secs(self.queue),
            self.pct(self.queue),
            fmt_secs(self.load_stall),
            self.pct(self.load_stall),
            fmt_secs(self.compute),
            self.pct(self.compute),
            fmt_secs(self.exposed),
            self.pct(self.exposed),
            fmt_secs(self.hidden),
            self.n,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("prefills", self.n.into()),
            ("retrieval_s", self.retrieval.into()),
            ("queue_s", self.queue.into()),
            ("load_stall_s", self.load_stall.into()),
            ("compute_s", self.compute.into()),
            ("exposed_transfer_s", self.exposed.into()),
            ("overlap_hidden_s", self.hidden.into()),
            ("ttft_mean_s", self.ttft.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(request: u64, queue: f64, compute: f64) -> RequestBreakdown {
        let (retrieval, load_stall, exposed) = (0.01, 0.05, 0.002);
        RequestBreakdown {
            request,
            retrieval,
            queue,
            load_stall,
            compute,
            exposed,
            hidden: 0.1,
            ttft: retrieval + queue + load_stall + compute + exposed,
        }
    }

    #[test]
    fn rows_reconcile_and_summary_averages() {
        let mut a = TtftAttribution::default();
        a.record(row(0, 0.2, 1.0));
        a.record(row(1, 0.4, 2.0));
        assert!(a.max_residual() < 1e-12);
        let s = a.summary();
        assert_eq!(s.n, 2);
        assert!((s.queue - 0.3).abs() < 1e-12);
        assert!((s.compute - 1.5).abs() < 1e-12);
        // summary means preserve the identity too
        let sum = s.retrieval + s.queue + s.load_stall + s.compute + s.exposed;
        assert!((sum - s.ttft).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_inert() {
        let a = TtftAttribution::default();
        let s = a.summary();
        assert!(!s.any());
        assert_eq!(s.n, 0);
        assert_eq!(s.ttft, 0.0);
        assert_eq!(a.max_residual(), 0.0);
    }

    #[test]
    fn absorb_merges_rows() {
        let mut a = TtftAttribution::default();
        let mut b = TtftAttribution::default();
        a.record(row(0, 0.2, 1.0));
        b.record(row(1, 0.4, 2.0));
        b.record(row(2, 0.6, 3.0));
        a.absorb(&b);
        assert_eq!(a.rows.len(), 3);
        assert_eq!(a.summary().n, 3);
    }

    #[test]
    fn pretty_and_json_expose_every_stage() {
        let mut a = TtftAttribution::default();
        a.record(row(0, 0.2, 1.0));
        let s = a.summary();
        let p = s.pretty();
        assert!(p.contains("ttft ="));
        assert!(p.contains("overlap hid"));
        assert!(p.contains("1 prefills"));
        let j = s.to_json();
        for k in [
            "prefills",
            "retrieval_s",
            "queue_s",
            "load_stall_s",
            "compute_s",
            "exposed_transfer_s",
            "overlap_hidden_s",
            "ttft_mean_s",
        ] {
            assert!(j.get(k).is_some(), "missing json key {k}");
        }
    }
}
