//! Periodic virtual-time gauge sampling and the flight recorder.
//!
//! [`TimelineSampler`] snapshots the engine's gauges (tier occupancy,
//! queue depth, inflight prefetches, windowed hit ratio) every
//! `interval` virtual seconds — the data behind occupancy/queue plots
//! (paper Figs 14–16 style). Dumpable as CSV or JSON.
//!
//! [`FlightRecorder`] captures the last-N trace events whenever a
//! degrade or failover counter fires, so a rare fault leaves behind
//! the exact event context that led up to it even when the full trace
//! ring has long since wrapped.

use crate::obs::trace::TraceEvent;
use crate::util::json::Json;

/// One gauge snapshot (all fields at virtual time `t`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimelineSample {
    pub t: f64,
    pub gpu_bytes: u64,
    pub dram_bytes: u64,
    pub ssd_bytes: u64,
    /// Requests waiting for a prefill slot.
    pub queue_depth: usize,
    /// Requests in their decode phase.
    pub decoding: usize,
    /// Prefetch transfers in flight.
    pub inflight_prefetch: usize,
    /// Chunk hit ratio over the window since the previous sample.
    pub hit_ratio_window: f64,
}

/// Samples gauges at a fixed virtual-time cadence. The engine asks
/// `due(now)` at the top of each step and pushes a sample when it
/// fires; `windowed_hit_ratio` turns the cache's monotonic counters
/// into a per-window ratio.
#[derive(Clone, Debug)]
pub struct TimelineSampler {
    interval: f64,
    next_due: f64,
    last_hits: u64,
    last_missed: u64,
    pub samples: Vec<TimelineSample>,
}

impl TimelineSampler {
    /// `interval` is virtual seconds between samples (must be > 0).
    pub fn new(interval: f64) -> Self {
        assert!(interval > 0.0, "timeline interval must be positive");
        TimelineSampler {
            interval,
            next_due: 0.0,
            last_hits: 0,
            last_missed: 0,
            samples: Vec::new(),
        }
    }

    pub fn due(&self, now: f64) -> bool {
        now >= self.next_due
    }

    /// Delta hit ratio since the last call, given the cache's
    /// monotonic total-hit / total-miss chunk counters.
    pub fn windowed_hit_ratio(&mut self, hits: u64, missed: u64) -> f64 {
        let dh = hits.saturating_sub(self.last_hits);
        let dm = missed.saturating_sub(self.last_missed);
        self.last_hits = hits;
        self.last_missed = missed;
        if dh + dm == 0 {
            0.0
        } else {
            dh as f64 / (dh + dm) as f64
        }
    }

    /// Record a sample and schedule the next one `interval` later.
    pub fn push(&mut self, s: TimelineSample) {
        self.next_due = s.t + self.interval;
        self.samples.push(s);
    }

    pub fn to_csv(&self) -> String {
        samples_to_csv(&self.samples)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("interval_s", self.interval.into()),
            ("samples", samples_to_json(&self.samples)),
        ])
    }
}

/// CSV dump of a bare sample slice (the form `RunOutcome::timeline`
/// carries once the sampler is consumed).
pub fn samples_to_csv(samples: &[TimelineSample]) -> String {
    let mut out = String::from(
        "t,gpu_bytes,dram_bytes,ssd_bytes,queue_depth,decoding,inflight_prefetch,hit_ratio_window\n",
    );
    for s in samples {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            s.t,
            s.gpu_bytes,
            s.dram_bytes,
            s.ssd_bytes,
            s.queue_depth,
            s.decoding,
            s.inflight_prefetch,
            s.hit_ratio_window
        ));
    }
    out
}

/// JSON array of a bare sample slice.
pub fn samples_to_json(samples: &[TimelineSample]) -> Json {
    let rows: Vec<Json> = samples
        .iter()
        .map(|s| {
            Json::from_pairs(vec![
                ("t", s.t.into()),
                ("gpu_bytes", s.gpu_bytes.into()),
                ("dram_bytes", s.dram_bytes.into()),
                ("ssd_bytes", s.ssd_bytes.into()),
                ("queue_depth", s.queue_depth.into()),
                ("decoding", s.decoding.into()),
                ("inflight_prefetch", s.inflight_prefetch.into()),
                ("hit_ratio_window", s.hit_ratio_window.into()),
            ])
        })
        .collect();
    rows.into()
}

/// Why a flight snapshot was taken.
pub const REASON_DEGRADE: &str = "degrade";
/// A replica was killed and its open requests re-routed.
pub const REASON_FAILOVER: &str = "failover";

/// The last-N trace events at the moment a degrade/failover fired.
#[derive(Clone, Debug, PartialEq)]
pub struct FlightSnapshot {
    pub t: f64,
    pub reason: &'static str,
    pub events: Vec<TraceEvent>,
}

/// Ring-of-snapshots: each trigger stores the tracer's recent tail.
/// Only meaningful when tracing is on (a null sink has no tail).
#[derive(Clone, Debug, Default)]
pub struct FlightRecorder {
    /// How many trailing events each snapshot keeps.
    pub depth: usize,
    pub snapshots: Vec<FlightSnapshot>,
}

impl FlightRecorder {
    pub fn new(depth: usize) -> Self {
        FlightRecorder { depth, snapshots: Vec::new() }
    }

    pub fn snapshot(&mut self, t: f64, reason: &'static str, events: Vec<TraceEvent>) {
        self.snapshots.push(FlightSnapshot { t, reason, events });
    }

    pub fn to_json(&self) -> Json {
        let snaps: Vec<Json> = self
            .snapshots
            .iter()
            .map(|s| {
                Json::from_pairs(vec![
                    ("t", s.t.into()),
                    ("reason", s.reason.into()),
                    ("n_events", s.events.len().into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![("depth", self.depth.into()), ("snapshots", snaps.into())])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Kind, Phase, Track};

    #[test]
    fn sampler_fires_on_cadence() {
        let mut tl = TimelineSampler::new(0.5);
        assert!(tl.due(0.0));
        tl.push(TimelineSample { t: 0.0, ..Default::default() });
        assert!(!tl.due(0.4));
        assert!(tl.due(0.5));
        tl.push(TimelineSample { t: 0.7, ..Default::default() });
        assert!(!tl.due(1.1));
        assert!(tl.due(1.2));
        assert_eq!(tl.samples.len(), 2);
    }

    #[test]
    fn windowed_hit_ratio_uses_deltas() {
        let mut tl = TimelineSampler::new(1.0);
        assert_eq!(tl.windowed_hit_ratio(0, 0), 0.0);
        assert!((tl.windowed_hit_ratio(8, 2) - 0.8).abs() < 1e-12);
        // next window: +2 hits, +2 misses
        assert!((tl.windowed_hit_ratio(10, 4) - 0.5).abs() < 1e-12);
        // idle window
        assert_eq!(tl.windowed_hit_ratio(10, 4), 0.0);
    }

    #[test]
    fn csv_and_json_carry_every_sample() {
        let mut tl = TimelineSampler::new(1.0);
        tl.push(TimelineSample { t: 0.0, gpu_bytes: 10, queue_depth: 3, ..Default::default() });
        tl.push(TimelineSample { t: 1.0, dram_bytes: 20, decoding: 2, ..Default::default() });
        let csv = tl.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("t,gpu_bytes"));
        assert!(csv.contains("0,10,0,0,3,0,0,0"));
        let j = tl.to_json();
        assert_eq!(j.get("samples").and_then(|s| s.as_arr()).unwrap().len(), 2);
    }

    #[test]
    fn flight_recorder_stores_reason_and_tail() {
        let mut fr = FlightRecorder::new(4);
        let evs = vec![TraceEvent {
            t: 1.0,
            track: Track::Cache,
            kind: Kind::CacheQuarantine,
            id: 5,
            phase: Phase::Instant,
        }];
        fr.snapshot(1.0, REASON_DEGRADE, evs.clone());
        fr.snapshot(2.0, REASON_FAILOVER, evs);
        assert_eq!(fr.snapshots.len(), 2);
        assert_eq!(fr.snapshots[0].reason, "degrade");
        assert_eq!(fr.snapshots[1].reason, "failover");
        let j = fr.to_json();
        assert_eq!(j.get("snapshots").and_then(|s| s.as_arr()).unwrap().len(), 2);
    }
}
