//! # Observability: tracing, TTFT attribution, and telemetry
//!
//! Three building blocks, all driven by the engine's deterministic
//! virtual clock so every artifact replays byte-identically at a
//! fixed seed:
//!
//! * [`trace`] — bounded ring-buffer span/event recorder behind the
//!   zero-cost [`TraceSink`] trait (null sink when disabled), exported
//!   as Chrome trace-event JSON.
//! * [`breakdown`] — per-request TTFT attribution
//!   (`retrieval + queue + load_stall + compute + exposed = ttft`,
//!   exact within 1e-9), aggregated into `Report::pretty` and
//!   `BENCH_ttft_breakdown.json` — the runnable analog of the paper's
//!   Table 1.
//! * [`timeline`] — periodic gauge sampler (tier occupancy, queue
//!   depth, inflight prefetches, windowed hit ratio) with CSV/JSON
//!   dump, plus a flight recorder that snapshots the last-N events
//!   when a degrade/failover counter fires.
//!
//! Configured by the `[obs]` TOML section (`obs.trace`,
//! `obs.trace_capacity`, `obs.timeline`, `obs.timeline_interval`,
//! `obs.flight_depth`) or the `--trace-out` / `--timeline-out` CLI
//! flags, which enable the matching recorder and write the artifact
//! after the run.
//!
//! # Event taxonomy
//!
//! | kind               | track           | phase     | meaning                                     |
//! |--------------------|-----------------|-----------|---------------------------------------------|
//! | `retrieval`        | `engine`        | b/e span  | arrival → documents ready (id = request)    |
//! | `queue`            | `engine`        | b/e span  | queued → popped by the scheduler            |
//! | `fault_prepass`    | `engine`        | instant   | fault pre-pass degraded/retried a plan      |
//! | `kv_load`          | `lane:*`        | X span    | one SSD chunk load occupying a lane         |
//! | `prefill`          | `engine`        | X span    | prefill attempt (dur = ssd_wait + pipeline) |
//! | `decode_round`     | `engine`        | X span    | one batched decode round                    |
//! | `cache_insert`     | `cache`         | instant   | chunk became resident (id = chunk key)      |
//! | `cache_hit`        | `cache`         | instant   | lookup matched a resident chunk             |
//! | `cache_evict`      | `cache`         | instant   | victim chunk left its last tier             |
//! | `cache_promote`    | `cache`         | instant   | chunk copied up a tier                      |
//! | `cache_demote`     | `cache`         | instant   | chunk dropped down / out of a tier          |
//! | `cache_quarantine` | `cache`         | instant   | corrupt subtree cut after a failed read     |
//! | `io_submit`        | `lane:prefetch` | instant   | prefetch enqueued (id = tree node)          |
//! | `io_complete`      | `lane:prefetch` | instant   | prefetch landed, chunk promoted             |
//! | `io_cancel`        | `lane:prefetch` | instant   | stale prefetch cancelled before start       |
//! | `io_upgrade`       | `lane:demand`   | instant   | demand fetch upgraded an in-flight prefetch |
//! | `route`            | `router`        | instant   | request routed to this replica              |
//! | `failover`         | `router`        | instant   | open request re-routed off a dead replica   |
//!
//! In the Chrome export: `pid` = replica index, `tid` = track name,
//! `ts`/`dur` = virtual seconds × 1e6 (the format's µs unit), and
//! `args.id` carries the request/chunk id in hex.
//!
//! # Adding a new trace event
//!
//! 1. Add a variant to [`trace::Kind`] and its `name()` /
//!    `category()` arms, and a row to the table above.
//! 2. At the instrumentation site, call
//!    `tracer.emit(|| TraceEvent { t, track, kind, id, phase })` —
//!    always through the closure so the disabled path stays free.
//!    Timestamps must come from the virtual clock (never wall time),
//!    or same-seed traces stop being byte-identical and the
//!    determinism tests in `serve::engine` fail.
//! 3. If the event should feed the flight recorder, nothing else is
//!    needed — snapshots copy the tracer's recent tail wholesale.
//!
//! # Adding a new metric
//!
//! * A per-request stage: extend [`breakdown::RequestBreakdown`] and
//!   keep `stage_sum` exact — the reconciliation proptest will fail
//!   the build if the stages stop summing to TTFT.
//! * A gauge: extend [`timeline::TimelineSample`] and the CSV/JSON
//!   writers; sample it where the engine fills the struct.
//! * A served counter: extend the Prometheus rendering in
//!   `serve::server` (`/metrics`), which follows the text exposition
//!   format — one `# TYPE` line plus one sample line per series.
//!
//! # Viewing a trace in Perfetto
//!
//! ```sh
//! cargo run --release -- sim --system pcr --trace-out trace.json
//! # or a fleet view, one pid per replica:
//! cargo run --release -- cluster --replicas 4 --trace-out trace.json
//! ```
//!
//! Open <https://ui.perfetto.dev>, drag `trace.json` in (or use
//! `chrome://tracing`). Request stages appear as async spans on the
//! `engine` track, lane transfers as duration slices on
//! `lane:demand` / `lane:prefetch`, and cache/router ticks as
//! instants. The ring keeps the newest `obs.trace_capacity` events;
//! the export notes nothing beyond what the ring retained (check
//! `trace_dropped` in the run summary when tuning capacity).

pub mod breakdown;
pub mod timeline;
pub mod trace;

pub use breakdown::{BreakdownSummary, RequestBreakdown, TtftAttribution};
pub use timeline::{FlightRecorder, FlightSnapshot, TimelineSample, TimelineSampler};
pub use trace::{chrome_trace, Kind, Phase, TraceEvent, TraceSink, Track, Tracer};
