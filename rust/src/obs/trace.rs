//! Bounded ring-buffer span/event recorder for the serving pipeline.
//!
//! Everything here runs in *virtual time* (the engine's deterministic
//! f64 clock), so a trace captured at a fixed seed is byte-identical
//! across runs — the determinism tests in `serve::engine` pin that.
//!
//! The recorder is zero-cost when disabled: [`Tracer::emit`] takes a
//! closure and never calls it unless tracing is on, so the off path is
//! a single branch on a bool and no event is ever constructed.
//!
//! Export is Chrome trace-event JSON (see [`chrome_trace`]) rendered
//! with the vendored deterministic [`Json`] writer — load the file at
//! <https://ui.perfetto.dev> or `chrome://tracing`.

use crate::util::json::Json;
use std::collections::VecDeque;

/// Which track (Perfetto `tid`) an event renders on. One process
/// (`pid`) per replica, one track per pipeline lane within it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Track {
    /// The request lifecycle: retrieval/queue spans, prefill, decode.
    Engine,
    /// Demand-lane transfers (KV loads the scheduled request needs now).
    LaneDemand,
    /// Prefetch-lane transfers (speculative SSD→DRAM promotions).
    LanePrefetch,
    /// Cache residency events (insert/hit/evict/promote/demote/...).
    Cache,
    /// Cluster routing and failover decisions.
    Router,
}

impl Track {
    pub fn name(self) -> &'static str {
        match self {
            Track::Engine => "engine",
            Track::LaneDemand => "lane:demand",
            Track::LanePrefetch => "lane:prefetch",
            Track::Cache => "cache",
            Track::Router => "router",
        }
    }
}

/// The event taxonomy — every span/instant the pipeline can emit.
/// The table in [`crate::obs`] documents each one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    // request-stage spans (track: engine)
    Retrieval,
    Queue,
    FaultPrepass,
    KvLoad,
    Prefill,
    DecodeRound,
    // cache events (track: cache)
    CacheInsert,
    CacheHit,
    CacheEvict,
    CachePromote,
    CacheDemote,
    CacheQuarantine,
    // io-lane events (track: lane:demand / lane:prefetch)
    IoSubmit,
    IoComplete,
    IoCancel,
    IoUpgrade,
    // cluster events (track: router)
    Route,
    Failover,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::Retrieval => "retrieval",
            Kind::Queue => "queue",
            Kind::FaultPrepass => "fault_prepass",
            Kind::KvLoad => "kv_load",
            Kind::Prefill => "prefill",
            Kind::DecodeRound => "decode_round",
            Kind::CacheInsert => "cache_insert",
            Kind::CacheHit => "cache_hit",
            Kind::CacheEvict => "cache_evict",
            Kind::CachePromote => "cache_promote",
            Kind::CacheDemote => "cache_demote",
            Kind::CacheQuarantine => "cache_quarantine",
            Kind::IoSubmit => "io_submit",
            Kind::IoComplete => "io_complete",
            Kind::IoCancel => "io_cancel",
            Kind::IoUpgrade => "io_upgrade",
            Kind::Route => "route",
            Kind::Failover => "failover",
        }
    }

    /// Chrome trace-event `cat` field: groups tracks when filtering.
    pub fn category(self) -> &'static str {
        match self {
            Kind::Retrieval
            | Kind::Queue
            | Kind::FaultPrepass
            | Kind::KvLoad
            | Kind::Prefill
            | Kind::DecodeRound => "stage",
            Kind::CacheInsert
            | Kind::CacheHit
            | Kind::CacheEvict
            | Kind::CachePromote
            | Kind::CacheDemote
            | Kind::CacheQuarantine => "cache",
            Kind::IoSubmit | Kind::IoComplete | Kind::IoCancel | Kind::IoUpgrade => "io",
            Kind::Route | Kind::Failover => "cluster",
        }
    }
}

/// How the event renders in the Chrome trace: async begin/end pairs
/// (overlapping request stages), complete spans with a duration
/// (serialized work), or zero-width instants (cache/io/router ticks).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Phase {
    /// Async span open (`ph: "b"`), matched by id.
    Begin,
    /// Async span close (`ph: "e"`), matched by id.
    End,
    /// Complete span (`ph: "X"`) with a duration in virtual seconds.
    Complete(f64),
    /// Instant event (`ph: "i"`).
    Instant,
}

/// One recorded event. `t` is virtual seconds; `id` carries the
/// request id for stage spans, the chunk-key/node payload for
/// cache/io events, and the replica/request id for cluster events.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub track: Track,
    pub kind: Kind,
    pub id: u64,
    pub phase: Phase,
}

/// Destination for recorded events. The engine only ever talks to the
/// sink through [`Tracer`], which guards every call behind the
/// enabled flag — a disabled tracer never constructs an event.
pub trait TraceSink: Send {
    fn record(&mut self, ev: TraceEvent);
    /// Drain everything recorded so far (oldest first).
    fn take(&mut self) -> Vec<TraceEvent>;
    /// Copy of the most recent `n` events (oldest first) — the flight
    /// recorder's snapshot source.
    fn recent(&self, n: usize) -> Vec<TraceEvent>;
    /// Events discarded because the ring was full.
    fn dropped(&self) -> u64;
}

/// Sink that discards everything — the disabled path.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn record(&mut self, _ev: TraceEvent) {}
    fn take(&mut self) -> Vec<TraceEvent> {
        Vec::new()
    }
    fn recent(&self, _n: usize) -> Vec<TraceEvent> {
        Vec::new()
    }
    fn dropped(&self) -> u64 {
        0
    }
}

/// Bounded FIFO ring: keeps the newest `cap` events, counts drops.
#[derive(Clone, Debug)]
pub struct RingSink {
    buf: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl RingSink {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        RingSink { buf: VecDeque::with_capacity(cap.min(4096)), cap, dropped: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl TraceSink for RingSink {
    fn record(&mut self, ev: TraceEvent) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    fn take(&mut self) -> Vec<TraceEvent> {
        self.buf.drain(..).collect()
    }

    fn recent(&self, n: usize) -> Vec<TraceEvent> {
        let skip = self.buf.len().saturating_sub(n);
        self.buf.iter().skip(skip).copied().collect()
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// The handle instrumentation sites hold. `emit` takes a closure so
/// the disabled path never builds the event — the whole call folds to
/// one predictable branch, which is what keeps the null-sink overhead
/// inside the hot-path budget.
pub struct Tracer {
    sink: Box<dyn TraceSink>,
    on: bool,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer").field("on", &self.on).finish()
    }
}

impl Tracer {
    /// Disabled tracer (null sink) — the default everywhere.
    pub fn off() -> Self {
        Tracer { sink: Box::new(NullSink), on: false }
    }

    /// Enabled tracer over a bounded ring of `cap` events.
    pub fn ring(cap: usize) -> Self {
        Tracer { sink: Box::new(RingSink::new(cap)), on: true }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Record an event. The closure is only called when tracing is on.
    #[inline]
    pub fn emit(&mut self, ev: impl FnOnce() -> TraceEvent) {
        if self.on {
            self.sink.record(ev());
        }
    }

    pub fn take(&mut self) -> Vec<TraceEvent> {
        self.sink.take()
    }

    pub fn recent(&self, n: usize) -> Vec<TraceEvent> {
        self.sink.recent(n)
    }

    pub fn dropped(&self) -> u64 {
        self.sink.dropped()
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::off()
    }
}

/// Render `(pid, events)` groups as Chrome trace-event JSON — one
/// `pid` per replica, one `tid` per [`Track`]. Timestamps are virtual
/// seconds scaled to microseconds (the format's unit). The output is
/// deterministic: object keys are sorted by the vendored writer and
/// array order is recording order.
pub fn chrome_trace(replicas: &[(usize, &[TraceEvent])]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for &(pid, evs) in replicas {
        for ev in evs {
            let mut pairs: Vec<(&str, Json)> = vec![
                ("name", ev.kind.name().into()),
                ("cat", ev.kind.category().into()),
                ("pid", pid.into()),
                ("tid", ev.track.name().into()),
                ("ts", (ev.t * 1e6).into()),
                ("args", Json::from_pairs(vec![("id", format!("{:x}", ev.id).into())])),
            ];
            match ev.phase {
                Phase::Begin => {
                    pairs.push(("ph", "b".into()));
                    pairs.push(("id", format!("{:x}", ev.id).into()));
                }
                Phase::End => {
                    pairs.push(("ph", "e".into()));
                    pairs.push(("id", format!("{:x}", ev.id).into()));
                }
                Phase::Complete(dur) => {
                    pairs.push(("ph", "X".into()));
                    pairs.push(("dur", (dur * 1e6).into()));
                }
                Phase::Instant => {
                    pairs.push(("ph", "i".into()));
                    pairs.push(("s", "t".into()));
                }
            }
            events.push(Json::from_pairs(pairs));
        }
    }
    Json::from_pairs(vec![
        ("traceEvents", events.into()),
        ("displayTimeUnit", "ms".into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, id: u64) -> TraceEvent {
        TraceEvent { t, track: Track::Engine, kind: Kind::Prefill, id, phase: Phase::Complete(0.5) }
    }

    #[test]
    fn ring_sink_bounds_and_counts_drops() {
        let mut s = RingSink::new(3);
        for i in 0..5 {
            s.record(ev(i as f64, i));
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.dropped(), 2);
        let out = s.take();
        assert_eq!(out.iter().map(|e| e.id).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert!(s.is_empty());
    }

    #[test]
    fn recent_returns_tail_oldest_first() {
        let mut s = RingSink::new(10);
        for i in 0..6 {
            s.record(ev(i as f64, i));
        }
        let tail = s.recent(2);
        assert_eq!(tail.iter().map(|e| e.id).collect::<Vec<_>>(), vec![4, 5]);
        // asking for more than recorded returns everything
        assert_eq!(s.recent(100).len(), 6);
    }

    #[test]
    fn disabled_tracer_never_builds_the_event() {
        let mut t = Tracer::off();
        let mut built = 0u32;
        t.emit(|| {
            built += 1;
            ev(0.0, 1)
        });
        assert_eq!(built, 0);
        assert!(!t.enabled());
        assert!(t.take().is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn enabled_tracer_records_in_order() {
        let mut t = Tracer::ring(16);
        t.emit(|| ev(1.0, 7));
        t.emit(|| TraceEvent {
            t: 2.0,
            track: Track::Cache,
            kind: Kind::CacheHit,
            id: 9,
            phase: Phase::Instant,
        });
        let out = t.take();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[1].kind, Kind::CacheHit);
    }

    #[test]
    fn chrome_export_is_valid_and_complete() {
        let span = |t, phase| TraceEvent {
            t,
            track: Track::Engine,
            kind: Kind::Retrieval,
            id: 1,
            phase,
        };
        let evs = vec![
            span(0.0, Phase::Begin),
            span(0.5, Phase::End),
            ev(1.0, 1),
            TraceEvent {
                t: 1.5,
                track: Track::Cache,
                kind: Kind::CacheEvict,
                id: 42,
                phase: Phase::Instant,
            },
        ];
        let doc = chrome_trace(&[(0, &evs)]);
        let text = doc.dump();
        let parsed = Json::parse(&text).expect("export must be valid json");
        let arr = parsed.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].get("ph").and_then(|p| p.as_str()), Some("b"));
        assert_eq!(arr[1].get("ph").and_then(|p| p.as_str()), Some("e"));
        assert_eq!(arr[2].get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(arr[3].get("ph").and_then(|p| p.as_str()), Some("i"));
        // µs scaling and track naming
        assert_eq!(arr[2].get("ts").and_then(|t| t.as_f64()), Some(1e6));
        assert_eq!(arr[2].get("dur").and_then(|d| d.as_f64()), Some(0.5e6));
        assert_eq!(arr[3].get("tid").and_then(|t| t.as_str()), Some("cache"));
        assert_eq!(arr[3].get("cat").and_then(|c| c.as_str()), Some("cache"));
    }

    #[test]
    fn chrome_export_separates_replica_pids() {
        let a = vec![ev(0.0, 1)];
        let b = vec![ev(0.0, 2)];
        let doc = chrome_trace(&[(0, &a), (1, &b)]);
        let arr = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap().clone();
        assert_eq!(arr[0].get("pid").and_then(|p| p.as_f64()), Some(0.0));
        assert_eq!(arr[1].get("pid").and_then(|p| p.as_f64()), Some(1.0));
    }
}
