//! Continuous-batching admission: select how many head-of-queue
//! requests fit one forward pass under a token budget (vLLM-style).
//! RAG inputs (~6.8k tokens) usually occupy a whole pass; the real-path
//! HTTP server batches many small requests per pass with this.

use crate::serve::queue::WaitingQueue;

/// Token-budget batcher.
#[derive(Clone, Debug)]
pub struct Batcher {
    /// Max new (computed) tokens per forward pass.
    pub max_batch_tokens: usize,
    /// Max requests per forward pass.
    pub max_batch_requests: usize,
}

impl Default for Batcher {
    fn default() -> Self {
        Batcher {
            max_batch_tokens: 8192,
            max_batch_requests: 16,
        }
    }
}

impl Batcher {
    /// How many requests from the queue head fit this pass. Always
    /// admits at least one (a single oversized request must still run).
    pub fn admit(&self, queue: &WaitingQueue) -> usize {
        let mut tokens = 0usize;
        let mut n = 0usize;
        for r in queue.iter() {
            let t = r.total_tokens();
            if n > 0 && (tokens + t > self.max_batch_tokens || n >= self.max_batch_requests) {
                break;
            }
            tokens += t;
            n += 1;
            if n >= self.max_batch_requests {
                break;
            }
        }
        n.max(usize::from(!queue.is_empty()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::ChunkedSeq;
    use crate::serve::request::Request;
    use std::sync::Arc;

    fn req(id: u64, tokens: usize) -> Request {
        let toks: Vec<u32> = (0..tokens as u32).collect();
        let chain = ChunkedSeq::new(&toks, 256);
        Request::new(id, id as u32, toks.into(), Arc::new(chain), 4, 0.0, 0.0)
    }

    #[test]
    fn admits_while_budget_lasts() {
        let b = Batcher {
            max_batch_tokens: 1000,
            max_batch_requests: 16,
        };
        let mut q = WaitingQueue::new();
        for i in 0..5 {
            q.push(req(i, 400));
        }
        assert_eq!(b.admit(&q), 2); // 400+400 fits, +400 does not
    }

    #[test]
    fn oversized_single_request_still_admitted() {
        let b = Batcher {
            max_batch_tokens: 100,
            max_batch_requests: 4,
        };
        let mut q = WaitingQueue::new();
        q.push(req(0, 7000));
        q.push(req(1, 7000));
        assert_eq!(b.admit(&q), 1);
    }

    #[test]
    fn request_cap_respected() {
        let b = Batcher {
            max_batch_tokens: 1_000_000,
            max_batch_requests: 3,
        };
        let mut q = WaitingQueue::new();
        for i in 0..10 {
            q.push(req(i, 10));
        }
        assert_eq!(b.admit(&q), 3);
    }

    #[test]
    fn empty_queue_admits_zero() {
        let b = Batcher::default();
        assert_eq!(b.admit(&WaitingQueue::new()), 0);
    }
}
