//! Minimal HTTP/1.1 JSON server over `std::net` (no hyper/tokio
//! offline) exposing the real-model serving path:
//!
//!   POST /generate  {"tokens": [...]}            -> generation + timing
//!   POST /rag       {"query": "free text"}       -> retrieve + generate
//!   GET  /stats                                  -> cache/latency stats
//!   GET  /metrics                                -> Prometheus text format
//!   GET  /healthz                                -> 200 ok
//!
//! One acceptor thread + a worker pool; the PJRT executor is behind a
//! mutex (single CPU "GPU"), which is exactly the paper's one-executor
//! regime — batching happens upstream in the scheduler.

use crate::cache::engine::CacheStats;
use crate::cache::tier::Tier;
use crate::io::IoStats;
use crate::rag::retriever::Retriever;
use crate::rag::tokenizer::Tokenizer;
use crate::runtime::executor::ExecutorHandle;
use crate::util::json::Json;
use crate::util::stats::Samples;
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Shared server state.
pub struct ServerState {
    pub executor: ExecutorHandle,
    pub retriever: Option<Retriever>,
    pub tokenizer: Tokenizer,
    pub ttft: Mutex<Samples>,
    pub requests: Mutex<u64>,
}

/// The serving HTTP frontend.
pub struct HttpServer {
    listener: TcpListener,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, state: ServerState) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        Ok(HttpServer {
            listener,
            state: Arc::new(state),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Handle for asking the serve loop to exit.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    pub fn state(&self) -> Arc<ServerState> {
        Arc::clone(&self.state)
    }

    /// Serve until the stop flag is set. Blocks the calling thread.
    pub fn serve(&self, workers: usize) -> Result<()> {
        let pool = ThreadPool::new(workers.max(1), "http");
        self.listener.set_nonblocking(true)?;
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let state = Arc::clone(&self.state);
                    pool.submit(move || {
                        let _ = handle_connection(stream, &state);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(e) => return Err(e.into()),
            }
        }
        pool.wait_idle();
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // headers
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(|v| v.trim().to_string())
        {
            content_length = v.parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let body_text = String::from_utf8_lossy(&body).to_string();

    // /metrics speaks the Prometheus text exposition format; every
    // other route answers JSON.
    let (code, payload, ctype) = if method == "GET" && path == "/metrics" {
        match metrics_text(state) {
            Ok(text) => (200u16, text, "text/plain; version=0.0.4"),
            Err(e) => (500, err_json(&e).dump(), "application/json"),
        }
    } else {
        let (code, response) = route(&method, &path, &body_text, state);
        (code, response.dump(), "application/json")
    };
    let mut stream = reader.into_inner();
    write!(
        stream,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        code,
        status_text(code),
        ctype,
        payload.len(),
        payload
    )?;
    stream.flush()?;
    Ok(())
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    }
}

fn route(method: &str, path: &str, body: &str, state: &ServerState) -> (u16, Json) {
    match (method, path) {
        ("GET", "/healthz") => (200, Json::from_pairs(vec![("ok", true.into())])),
        ("GET", "/stats") => (200, stats_json(state)),
        ("POST", "/generate") => match handle_generate(body, state) {
            Ok(j) => (200, j),
            Err(e) => (400, err_json(&e)),
        },
        ("POST", "/rag") => match handle_rag(body, state) {
            Ok(j) => (200, j),
            Err(e) => (400, err_json(&e)),
        },
        _ => (404, err_json(&anyhow!("no such route"))),
    }
}

fn err_json(e: &anyhow::Error) -> Json {
    Json::from_pairs(vec![("error", format!("{e:#}").into())])
}

fn stats_json(state: &ServerState) -> Json {
    let mut ttft = state.ttft.lock().unwrap();
    let requests = *state.requests.lock().unwrap();
    let exec_stats = match state.executor.stats() {
        Ok(s) => s,
        Err(e) => return err_json(&e),
    };
    let stats = exec_stats.cache;
    let io = exec_stats.io.unwrap_or_default();
    Json::from_pairs(vec![
        ("requests", requests.into()),
        ("ttft_mean_s", if ttft.is_empty() { Json::Null } else { ttft.mean().into() }),
        ("ttft_p99_s", if ttft.is_empty() { Json::Null } else { ttft.percentile(99.0).into() }),
        ("cache_hit_ratio", stats.hit_ratio().into()),
        ("hits_dram", stats.hit_chunks[1].into()),
        ("hits_ssd", stats.hit_chunks[2].into()),
        ("evictions_dram", stats.evicted_chunks[1].into()),
        // transfer-engine lane counters (all zero without an SSD tier)
        ("io_demand_completed", io.demand.completed.into()),
        ("io_prefetch_completed", io.prefetch.completed.into()),
        ("io_prefetch_cancelled", io.prefetch.cancelled.into()),
        ("io_deduped", (io.demand.deduped + io.prefetch.deduped).into()),
        ("io_upgraded", io.upgraded.into()),
        ("io_demand_mean_wait_s", io.demand.mean_wait().into()),
        ("io_prefetch_mean_wait_s", io.prefetch.mean_wait().into()),
    ])
}

/// Gather the live counters and render them for a Prometheus scrape.
fn metrics_text(state: &ServerState) -> Result<String> {
    let requests = *state.requests.lock().unwrap();
    let mut ttft = state.ttft.lock().unwrap();
    let ttft_s = if ttft.is_empty() {
        None
    } else {
        Some((ttft.mean(), ttft.percentile(99.0)))
    };
    let exec = state.executor.stats()?;
    Ok(prometheus_text(
        requests,
        ttft_s,
        &exec.cache,
        &exec.io.unwrap_or_default(),
        exec.store_errors,
    ))
}

/// Render the Prometheus text exposition format (version 0.0.4): a
/// `# TYPE` line followed by the samples for each series. Pure so the
/// format can be pinned by a unit test without binding a socket.
/// `ttft` is `(mean_s, p99_s)` — `None` before the first request, in
/// which case the TTFT gauges are omitted (Prometheus treats an
/// absent series as "no data", which is more honest than 0).
pub fn prometheus_text(
    requests: u64,
    ttft: Option<(f64, f64)>,
    cache: &CacheStats,
    io: &IoStats,
    store_errors: u64,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "# TYPE pcr_requests_total counter");
    let _ = writeln!(s, "pcr_requests_total {requests}");
    if let Some((mean, p99)) = ttft {
        let _ = writeln!(s, "# TYPE pcr_ttft_seconds_mean gauge");
        let _ = writeln!(s, "pcr_ttft_seconds_mean {mean}");
        let _ = writeln!(s, "# TYPE pcr_ttft_seconds_p99 gauge");
        let _ = writeln!(s, "pcr_ttft_seconds_p99 {p99}");
    }
    let _ = writeln!(s, "# TYPE pcr_cache_hit_ratio gauge");
    let _ = writeln!(s, "pcr_cache_hit_ratio {}", cache.hit_ratio());
    let _ = writeln!(s, "# TYPE pcr_cache_hits_total counter");
    for t in Tier::ALL {
        let hits = cache.hit_chunks[t.idx()];
        let _ = writeln!(s, "pcr_cache_hits_total{{tier=\"{}\"}} {}", t.name(), hits);
    }
    let _ = writeln!(s, "# TYPE pcr_cache_misses_total counter");
    let _ = writeln!(s, "pcr_cache_misses_total {}", cache.missed_chunks);
    let _ = writeln!(s, "# TYPE pcr_cache_evictions_total counter");
    for t in Tier::ALL {
        let ev = cache.evicted_chunks[t.idx()];
        let _ = writeln!(s, "pcr_cache_evictions_total{{tier=\"{}\"}} {}", t.name(), ev);
    }
    let _ = writeln!(s, "# TYPE pcr_io_completed_total counter");
    let _ = writeln!(s, "pcr_io_completed_total{{lane=\"demand\"}} {}", io.demand.completed);
    let _ = writeln!(s, "pcr_io_completed_total{{lane=\"prefetch\"}} {}", io.prefetch.completed);
    let _ = writeln!(s, "# TYPE pcr_io_cancelled_total counter");
    let _ = writeln!(s, "pcr_io_cancelled_total{{lane=\"prefetch\"}} {}", io.prefetch.cancelled);
    let _ = writeln!(s, "# TYPE pcr_io_upgraded_total counter");
    let _ = writeln!(s, "pcr_io_upgraded_total {}", io.upgraded);
    // the degrade series: store-level errors absorbed by the
    // graceful-degradation path (nonzero means recompute fallbacks)
    let _ = writeln!(s, "# TYPE pcr_degrade_store_errors_total counter");
    let _ = writeln!(s, "pcr_degrade_store_errors_total {store_errors}");
    s
}

fn parse_tokens(j: &Json, vocab: u32) -> Result<Vec<u32>> {
    let arr = j
        .get("tokens")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("body must carry a 'tokens' array"))?;
    arr.iter()
        .map(|t| {
            let x = t.as_f64().ok_or_else(|| anyhow!("non-numeric token"))? as i64;
            if x < 0 || x >= vocab as i64 {
                Err(anyhow!("token {x} outside vocab {vocab}"))
            } else {
                Ok(x as u32)
            }
        })
        .collect()
}

fn handle_generate(body: &str, state: &ServerState) -> Result<Json> {
    let j = Json::parse(body).map_err(|e| anyhow!("{e}"))?;
    let vocab = state.executor.stats()?.vocab as u32;
    let tokens = parse_tokens(&j, vocab)?;
    serve_tokens(&tokens, state)
}

fn handle_rag(body: &str, state: &ServerState) -> Result<Json> {
    let j = Json::parse(body).map_err(|e| anyhow!("{e}"))?;
    let query = j
        .get("query")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("body must carry a 'query' string"))?;
    let retriever = state
        .retriever
        .as_ref()
        .ok_or_else(|| anyhow!("server started without a retriever"))?;
    let q_tokens = state.tokenizer.encode(query);
    let retrieval = retriever.retrieve(&q_tokens);
    let mut out = serve_tokens(&retrieval.tokens, state)?;
    out.set(
        "doc_ids",
        Json::Arr(retrieval.doc_ids.iter().map(|d| (*d as u64).into()).collect()),
    );
    out.set("retrieval_s", retrieval.search_seconds.into());
    Ok(out)
}

fn serve_tokens(tokens: &[u32], state: &ServerState) -> Result<Json> {
    let result = state.executor.serve(tokens.to_vec())?;
    state.ttft.lock().unwrap().push(result.prefill_seconds);
    *state.requests.lock().unwrap() += 1;
    Ok(Json::from_pairs(vec![
        ("first_token", (result.first_token as u64).into()),
        ("prefill_s", result.prefill_seconds.into()),
        ("reused_tokens", result.reused_tokens.into()),
        ("computed_tokens", result.computed_tokens.into()),
        ("reused_from_dram", result.reused_from_dram.into()),
        ("reused_from_ssd", result.reused_from_ssd.into()),
        ("passes", result.passes.into()),
    ]))
}

/// Tiny blocking HTTP client for tests and the load-driver example.
pub fn http_request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, Json)> {
    let (code, text) = http_request_text(addr, method, path, body)?;
    let j = Json::parse(text.trim()).map_err(|e| anyhow!("{e}"))?;
    Ok((code, j))
}

/// Like [`http_request`] but returns the raw response body — needed
/// for non-JSON routes such as the Prometheus `/metrics` scrape.
pub fn http_request_text(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut response = String::new();
    BufReader::new(stream).read_to_string(&mut response)?;
    let code: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("malformed response"))?;
    let body_start = response
        .find("\r\n\r\n")
        .ok_or_else(|| anyhow!("no body"))?;
    Ok((code, response[body_start + 4..].to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{default_artifacts_dir, Manifest};

    #[test]
    fn prometheus_text_renders_every_series_with_a_type_line() {
        use crate::io::LaneStats;
        let cache = CacheStats {
            hit_chunks: [1, 2, 3],
            missed_chunks: 6,
            evicted_chunks: [0, 4, 0],
            ..Default::default()
        };
        let io = IoStats {
            demand: LaneStats { completed: 9, ..Default::default() },
            prefetch: LaneStats { completed: 5, cancelled: 2, ..Default::default() },
            upgraded: 4,
            ..Default::default()
        };
        let text = prometheus_text(7, Some((0.25, 0.5)), &cache, &io, 2);
        assert!(text.contains("pcr_requests_total 7"));
        assert!(text.contains("pcr_ttft_seconds_mean 0.25"));
        assert!(text.contains("pcr_ttft_seconds_p99 0.5"));
        assert!(text.contains("pcr_cache_hit_ratio 0.5"), "{text}");
        assert!(text.contains("pcr_cache_hits_total{tier=\"dram\"} 2"));
        assert!(text.contains("pcr_cache_hits_total{tier=\"ssd\"} 3"));
        assert!(text.contains("pcr_cache_evictions_total{tier=\"dram\"} 4"));
        assert!(text.contains("pcr_io_completed_total{lane=\"demand\"} 9"));
        assert!(text.contains("pcr_io_cancelled_total{lane=\"prefetch\"} 2"));
        assert!(text.contains("pcr_io_upgraded_total 4"));
        assert!(text.contains("pcr_degrade_store_errors_total 2"));
        // every emitted sample line belongs to a `# TYPE`-declared series
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let name = line.split(['{', ' ']).next().unwrap();
            assert!(
                text.contains(&format!("# TYPE {name} ")),
                "series {name} has no TYPE line"
            );
        }
        // before the first request the TTFT gauges are absent entirely
        let cold = prometheus_text(0, None, &cache, &io, 0);
        assert!(!cold.contains("pcr_ttft_seconds"));
        assert!(cold.contains("pcr_requests_total 0"));
    }

    /// Spin a real server (if artifacts exist) and poke every route.
    #[test]
    fn full_http_round_trip() {
        let Ok(manifest) = Manifest::load(default_artifacts_dir()) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let dir = std::env::temp_dir().join(format!("pcr-http-{}", std::process::id()));
        let executor = ExecutorHandle::spawn(move || {
            crate::runtime::executor::PjrtExecutor::new(manifest, 32, 64, Some(&dir), "")
        })
        .unwrap();
        let state = ServerState {
            executor,
            retriever: None,
            tokenizer: Tokenizer::new(2048),
            ttft: Mutex::new(Samples::new()),
            requests: Mutex::new(0),
        };
        let server = HttpServer::bind("127.0.0.1:0", state).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stop = server.stop_handle();
        let handle = std::thread::spawn(move || server.serve(2));

        let (code, j) = http_request(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!(code, 200);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));

        // generate twice with the same tokens: the second reuses
        let tokens: Vec<u64> = (0..300u64).map(|i| i % 512).collect();
        let body = Json::from_pairs(vec![(
            "tokens",
            Json::Arr(tokens.iter().map(|t| (*t).into()).collect()),
        )])
        .dump();
        let (code, j1) = http_request(&addr, "POST", "/generate", &body).unwrap();
        assert_eq!(code, 200, "{j1}");
        assert_eq!(j1.get("reused_tokens").unwrap().as_usize(), Some(0));
        let (_, j2) = http_request(&addr, "POST", "/generate", &body).unwrap();
        assert_eq!(j2.get("reused_tokens").unwrap().as_usize(), Some(256));
        assert_eq!(
            j1.get("first_token").unwrap().as_usize(),
            j2.get("first_token").unwrap().as_usize()
        );

        let (code, stats) = http_request(&addr, "GET", "/stats", "").unwrap();
        assert_eq!(code, 200);
        assert_eq!(stats.get("requests").unwrap().as_usize(), Some(2));
        // transfer-engine counters are exported (zeros are fine here —
        // both requests hit DRAM)
        assert!(stats.get("io_upgraded").is_some());
        assert!(stats.get("io_demand_completed").is_some());

        // Prometheus scrape: text content, TTFT + hit-ratio + degrade
        // series all present after two served requests
        let (code, scrape) = http_request_text(&addr, "GET", "/metrics", "").unwrap();
        assert_eq!(code, 200);
        assert!(scrape.contains("pcr_requests_total 2"), "{scrape}");
        assert!(scrape.contains("pcr_ttft_seconds_mean "), "{scrape}");
        assert!(scrape.contains("pcr_ttft_seconds_p99 "), "{scrape}");
        assert!(scrape.contains("pcr_cache_hit_ratio "), "{scrape}");
        assert!(scrape.contains("pcr_degrade_store_errors_total "), "{scrape}");

        // error paths
        let (code, _) = http_request(&addr, "POST", "/generate", "{}").unwrap();
        assert_eq!(code, 400);
        let (code, _) = http_request(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(code, 404);

        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap().unwrap();
    }
}
