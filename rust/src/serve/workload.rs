//! Workload construction (paper §6.1).
//!
//! 1. Generate the synthetic corpus and build the HNSW retriever.
//! 2. Sample `n_inputs` distinct queries (Zipf topics); retrieve top-k
//!    docs for each; the assembled `[docs ‖ query]` sequences form the
//!    *dataset*.
//! 3. Issue `n_requests` requests by sampling the dataset **with
//!    replacement** (workload 1, paper's "oversampling") or by cycling a
//!    shuffle **without** replacement (workload 2).
//! 4. Arrival times follow a Poisson process at the configured rate.
//!
//! The dataset-level *repetition ratio* (fraction of issued requests
//! whose input already appeared) is measured and reported — the paper
//! quotes ~40% (W1) and ~35% (W2).

use crate::cache::chunk::ChunkedSeq;
use crate::config::ExperimentConfig;
use crate::rag::corpus::{Corpus, CorpusConfig};
use crate::rag::retriever::Retriever;
use crate::util::rng::Rng;
use std::sync::Arc;

/// One issued request (before serving).
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub arrival: f64,
    pub input_id: u32,
    /// Shared token slice: one allocation per distinct input, shared
    /// by every repeat of it and by every admitted `Request`.
    pub tokens: Arc<[u32]>,
    pub chain: Arc<ChunkedSeq>,
    /// Seconds the (real) index search took when the dataset was built
    /// — replayed as the retrieval latency in the simulator.
    pub retrieval_seconds: f64,
}

/// A full experiment workload.
#[derive(Clone, Debug)]
pub struct Workload {
    pub items: Vec<WorkItem>,
    pub n_distinct_inputs: usize,
    /// Fraction of requests whose input was seen before (the paper's
    /// repetition ratio).
    pub repetition_ratio: f64,
    pub mean_input_tokens: f64,
}

impl Workload {
    /// Build the dataset + request stream for `cfg`.
    pub fn build(cfg: &ExperimentConfig) -> Workload {
        let corpus = Corpus::generate(CorpusConfig {
            n_docs: cfg.n_docs,
            n_topics: cfg.n_topics,
            vocab: 2048,
            mean_doc_tokens: cfg.mean_doc_tokens,
            doc_tokens_jitter: 0.2,
            seed: cfg.seed ^ 0xC0_FFEE,
        });
        let retriever = Retriever::build(corpus, cfg.docs_per_query);
        let mut rng = Rng::new(cfg.seed ^ 0xDA7A_5E7);

        // --- dataset ---
        let mut inputs: Vec<(Arc<[u32]>, Arc<ChunkedSeq>, f64)> =
            Vec::with_capacity(cfg.n_inputs);
        for _ in 0..cfg.n_inputs {
            let q = retriever.sample_query(&mut rng, cfg.query_tokens);
            let out = retriever.retrieve(&q);
            let chain = ChunkedSeq::new(&out.tokens, cfg.chunk_tokens);
            inputs.push((
                out.tokens.into(),
                Arc::new(chain),
                out.search_seconds,
            ));
        }

        // --- request stream ---
        let mut order: Vec<u32> = Vec::with_capacity(cfg.n_requests);
        if cfg.oversample {
            // workload 1: full sampling then oversampling with
            // replacement (paper wording) == uniform with replacement
            for _ in 0..cfg.n_requests {
                order.push(rng.below(cfg.n_inputs as u64) as u32);
            }
        } else {
            // workload 2: full sampling without oversampling: cycle
            // through shuffled permutations
            let mut perm: Vec<u32> = (0..cfg.n_inputs as u32).collect();
            while order.len() < cfg.n_requests {
                rng.shuffle(&mut perm);
                for &i in &perm {
                    if order.len() == cfg.n_requests {
                        break;
                    }
                    order.push(i);
                }
            }
        }

        // Poisson arrivals
        let mut t = 0.0;
        let mut items = Vec::with_capacity(cfg.n_requests);
        let mut seen = vec![false; cfg.n_inputs];
        let mut repeats = 0usize;
        for &input_id in &order {
            t += rng.exponential(cfg.rate);
            let (tokens, chain, rs) = &inputs[input_id as usize];
            if seen[input_id as usize] {
                repeats += 1;
            }
            seen[input_id as usize] = true;
            items.push(WorkItem {
                arrival: t,
                input_id,
                tokens: Arc::clone(tokens),
                chain: Arc::clone(chain),
                retrieval_seconds: *rs,
            });
        }
        let mean_tokens = items
            .iter()
            .map(|i| i.tokens.len() as f64)
            .sum::<f64>()
            / items.len().max(1) as f64;
        Workload {
            n_distinct_inputs: cfg.n_inputs,
            repetition_ratio: repeats as f64 / order.len().max(1) as f64,
            mean_input_tokens: mean_tokens,
            items,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(oversample: bool, rate: f64) -> ExperimentConfig {
        ExperimentConfig {
            n_inputs: 50,
            n_requests: 200,
            oversample,
            rate,
            n_docs: 200,
            n_topics: 16,
            mean_doc_tokens: 300,
            query_tokens: 32,
            chunk_tokens: 64,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_build() {
        let cfg = small_cfg(true, 1.0);
        let a = Workload::build(&cfg);
        let b = Workload::build(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.input_id, y.input_id);
            assert!((x.arrival - y.arrival).abs() < 1e-12);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_consistent() {
        let cfg = small_cfg(true, 2.0);
        let w = Workload::build(&cfg);
        let mut prev = 0.0;
        for item in &w.items {
            assert!(item.arrival > prev);
            prev = item.arrival;
        }
        // mean inter-arrival ≈ 1/rate
        let span = w.items.last().unwrap().arrival;
        let mean_gap = span / w.len() as f64;
        assert!((mean_gap - 0.5).abs() < 0.12, "mean_gap={mean_gap}");
    }

    #[test]
    fn oversampled_workload_repeats_heavily() {
        let w = Workload::build(&small_cfg(true, 1.0));
        // 200 draws from 50 inputs: most are repeats
        assert!(w.repetition_ratio > 0.5, "rep={}", w.repetition_ratio);
    }

    #[test]
    fn without_replacement_covers_all_inputs_first() {
        let w = Workload::build(&small_cfg(false, 1.0));
        let first_50: std::collections::HashSet<u32> =
            w.items[..50].iter().map(|i| i.input_id).collect();
        assert_eq!(first_50.len(), 50); // a full permutation before repeats
    }

    #[test]
    fn repeated_inputs_share_token_arcs() {
        let w = Workload::build(&small_cfg(true, 1.0));
        // find two items with the same input id — their Arc should be
        // the same allocation (prefix reuse is byte-identical)
        let mut by_input: std::collections::HashMap<u32, &WorkItem> =
            std::collections::HashMap::new();
        let mut shared = false;
        for item in &w.items {
            if let Some(prev) = by_input.get(&item.input_id) {
                assert!(Arc::ptr_eq(&prev.tokens, &item.tokens));
                assert_eq!(prev.chain.keys, item.chain.keys);
                shared = true;
            }
            by_input.insert(item.input_id, item);
        }
        assert!(shared);
    }

    #[test]
    fn paper_scale_repetition_ratios() {
        // Paper: W1 (1000 inputs, oversampled to 2000) ~40%; W2 (2000
        // inputs, no oversampling) ~35%. Our W1 analogue: 2000 draws
        // from 1000 inputs gives ~ 1 - (1000/2000)*(1-e^-2) ≈ 57%
        // cumulative repeats; the paper's 40% counts duplicate *pairs* —
        // either way, W1 must repeat more than W2 at equal scale.
        let mut c1 = small_cfg(true, 1.0);
        c1.n_inputs = 100;
        c1.n_requests = 200;
        let mut c2 = small_cfg(false, 1.0);
        c2.n_inputs = 200;
        c2.n_requests = 200;
        let w1 = Workload::build(&c1);
        let w2 = Workload::build(&c2);
        assert!(w1.repetition_ratio > w2.repetition_ratio);
    }

    #[test]
    fn mean_input_length_tracks_doc_config() {
        let w = Workload::build(&small_cfg(true, 1.0));
        // 2 docs * ~300 + 32 query ≈ 630 tokens
        assert!((w.mean_input_tokens - 630.0).abs() < 150.0,
                "mean={}", w.mean_input_tokens);
    }
}
