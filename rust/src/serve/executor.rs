//! Step-time executors.
//!
//! [`SimExecutor`] turns a movement plan into virtual-time durations
//! using the calibrated cost models: SSD demand loads gate the pipeline
//! (that's the latency prefetch removes), then the layer-wise 3-stream
//! pipeline covers H2D upload, compute, and D2H offload per Fig 8. The
//! real-model executor lives in `runtime::PjrtExecutor` and shares the
//! same trait so the serving engine is oblivious to which one runs.

use crate::hw::gpu::GpuCostModel;
use crate::hw::spec::{ModelSpec, PlatformSpec};
use crate::hw::transfer::{chunk_copy_time, CopyMode, TransferFabric};
use crate::serve::scheduler::MovementPlan;
use crate::serve::system::SystemSpec;
use crate::sim::pipeline::{makespan, LayerTimings, OverlapMode};

/// vLLM paged-KV block size in tokens (paper: 16 vs chunk 256).
pub const VLLM_BLOCK_TOKENS: u64 = 16;

/// Per-layer stream-synchronization overhead (event record/wait) — what
/// makes full overlap non-free for tiny-KV models (Fig 18's Qwen case).
pub const STREAM_SYNC_OVERHEAD_S: f64 = 1.2e-4;

/// Durations of one prefill step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepBreakdown {
    /// Wait for SSD demand loads before the pipeline can run.
    pub ssd_wait: f64,
    /// Layer-wise pipeline makespan (upload+compute+offload).
    pub pipeline: f64,
    /// Pure compute inside the pipeline (for utilization reporting).
    pub compute: f64,
    /// Upload / offload lane sums (for utilization reporting).
    pub upload: f64,
    pub offload: f64,
}

impl StepBreakdown {
    pub fn total(&self) -> f64 {
        self.ssd_wait + self.pipeline
    }
}

/// Virtual-time executor over the analytic cost models.
#[derive(Clone, Debug)]
pub struct SimExecutor {
    pub gpu: GpuCostModel,
    pub model: ModelSpec,
    pub platform: PlatformSpec,
    pub chunk_tokens: u64,
}

impl SimExecutor {
    pub fn new(model: &ModelSpec, platform: &PlatformSpec, chunk_tokens: usize) -> Self {
        SimExecutor {
            gpu: GpuCostModel::new(model, platform),
            model: model.clone(),
            platform: platform.clone(),
            chunk_tokens: chunk_tokens as u64,
        }
    }

    /// Time for one prefill forward pass given the movement plan.
    ///
    /// `ssd_ready_at` is the absolute time at which the last demand
    /// SSD→DRAM load lands (computed by the engine against the shared
    /// SSD read channel, so prefetch backlog and demand loads contend);
    /// `now` is the step start.
    pub fn prefill_step(
        &self,
        now: f64,
        ssd_ready_at: f64,
        plan: &MovementPlan,
        spec: &SystemSpec,
        fabric: &mut TransferFabric,
    ) -> StepBreakdown {
        let n_layers = self.model.n_layers as usize;
        let copy_mode = if spec.batch_async {
            CopyMode::BatchAsync
        } else {
            CopyMode::BlockByBlock
        };

        // Upload lane: DRAM-resident + (now DRAM-landed) SSD chunks.
        let up_chunks = (plan.from_dram + plan.from_ssd) as u64;
        let per_layer_chunk_up =
            chunk_copy_time(&fabric.h2d, &self.model, self.chunk_tokens,
                            VLLM_BLOCK_TOKENS, copy_mode);
        let up_per_layer = up_chunks as f64 * per_layer_chunk_up;

        // Offload lane: all newly generated full chunks go back to DRAM
        // (the paper offloads the entire new KV; the non-chunk-aligned
        // tail is skipped because it is never cacheable).
        let down_chunks = if spec.dram_tier {
            plan.computed_chunks as u64
        } else {
            0
        };
        let per_layer_chunk_down =
            chunk_copy_time(&fabric.d2h, &self.model, self.chunk_tokens,
                            VLLM_BLOCK_TOKENS, copy_mode);
        let down_per_layer = down_chunks as f64 * per_layer_chunk_down;

        // Compute lane.
        let compute_total = self
            .gpu
            .prefill_time(plan.reused_tokens as u64, plan.computed_tokens as u64);
        let compute_per_layer = compute_total / n_layers as f64;

        let timings = LayerTimings {
            up: vec![up_per_layer; n_layers],
            compute: vec![compute_per_layer; n_layers],
            down: vec![down_per_layer; n_layers],
            sync_overhead: STREAM_SYNC_OVERHEAD_S,
        };
        // Sync mode has no per-layer stream synchronization.
        let timings = if spec.overlap == OverlapMode::Sync {
            LayerTimings {
                sync_overhead: 0.0,
                ..timings
            }
        } else {
            timings
        };
        let pipeline = makespan(&timings, spec.overlap);

        // Account the PCIe traffic on the fabric cursors (keeps
        // utilization metrics honest; latency already in `pipeline`).
        let up_bytes = self.model.kv_bytes_per_token()
            * up_chunks * self.chunk_tokens;
        let down_bytes = self.model.kv_bytes_per_token()
            * down_chunks * self.chunk_tokens;
        fabric.h2d.bytes_moved += up_bytes;
        fabric.d2h.bytes_moved += down_bytes;

        StepBreakdown {
            ssd_wait: (ssd_ready_at - now).max(0.0),
            pipeline,
            compute: compute_total,
            upload: up_per_layer * n_layers as f64,
            offload: down_per_layer * n_layers as f64,
        }
    }

    /// One fused decode round for a batch at max context `ctx`.
    pub fn decode_round(&self, ctx: u64) -> f64 {
        self.gpu.decode_time(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::spec::{model_spec, platform_spec};
    use crate::serve::system::SystemSpec;

    fn setup() -> (SimExecutor, TransferFabric) {
        let m = model_spec("llama2-7b").unwrap();
        let p = platform_spec("a6000").unwrap();
        (SimExecutor::new(&m, &p, 256), TransferFabric::new(&p))
    }

    fn plan(gpu: usize, dram: usize, ssd: usize, computed_chunks: usize) -> MovementPlan {
        MovementPlan {
            matched: Vec::new(),
            from_gpu: gpu,
            from_dram: dram,
            from_ssd: ssd,
            ssd_nodes: Vec::new(),
            reused_tokens: (gpu + dram + ssd) * 256,
            computed_tokens: computed_chunks * 256 + 64,
            computed_chunks,
        }
    }

    #[test]
    fn overlap_beats_sync_for_mha_model() {
        let (ex, mut fab) = setup();
        let p = plan(0, 13, 0, 13); // half reused from DRAM
        let sync = ex.prefill_step(0.0, 0.0, &p,
            &SystemSpec::pcr_base(), &mut fab);
        let ovl = ex.prefill_step(0.0, 0.0, &p,
            &SystemSpec::named("pcr", 4).unwrap(), &mut fab);
        assert!(ovl.total() < sync.total(),
                "ovl={} sync={}", ovl.total(), sync.total());
        // overlap hides most transfer: pipeline ≈ compute + ~2 layers
        assert!(ovl.pipeline < sync.pipeline);
        assert!(ovl.pipeline - ovl.compute < 0.25 * (sync.pipeline - sync.compute));
    }

    #[test]
    fn ssd_wait_is_gated_by_ready_time() {
        let (ex, mut fab) = setup();
        let p = plan(0, 5, 8, 13);
        let b = ex.prefill_step(10.0, 12.5, &p,
            &SystemSpec::named("pcr", 4).unwrap(), &mut fab);
        assert!((b.ssd_wait - 2.5).abs() < 1e-12);
        assert!(b.total() > 2.5);
        // already-ready SSD chunks cost nothing extra
        let b2 = ex.prefill_step(10.0, 9.0, &p,
            &SystemSpec::named("pcr", 4).unwrap(), &mut fab);
        assert_eq!(b2.ssd_wait, 0.0);
    }

    #[test]
    fn vllm_has_no_transfer_lanes() {
        let (ex, mut fab) = setup();
        let p = plan(10, 0, 0, 13);
        let b = ex.prefill_step(0.0, 0.0, &p,
            &SystemSpec::named("vllm", 0).unwrap(), &mut fab);
        assert_eq!(b.upload, 0.0);
        assert_eq!(b.offload, 0.0);
        assert!((b.pipeline - b.compute).abs() < 1e-9);
    }

    #[test]
    fn reuse_shrinks_total_time() {
        let (ex, mut fab) = setup();
        let all_compute = plan(0, 0, 0, 26);
        let half_reused = plan(0, 13, 0, 13);
        let spec = SystemSpec::named("pcr", 4).unwrap();
        let a = ex.prefill_step(0.0, 0.0, &all_compute, &spec, &mut fab);
        let b = ex.prefill_step(0.0, 0.0, &half_reused, &spec, &mut fab);
        assert!(b.total() < 0.75 * a.total(),
                "b={} a={}", b.total(), a.total());
    }

    #[test]
    fn batch_async_strictly_faster_upload() {
        let (ex, mut fab) = setup();
        let p = plan(0, 13, 0, 13);
        let fast = ex.prefill_step(0.0, 0.0, &p,
            &SystemSpec::named("pcr", 4).unwrap(), &mut fab);
        let mut slow_spec = SystemSpec::named("pcr", 4).unwrap();
        slow_spec.batch_async = false;
        let slow = ex.prefill_step(0.0, 0.0, &p, &slow_spec, &mut fab);
        assert!(slow.upload > fast.upload);
    }

    #[test]
    fn decode_round_scales_with_context() {
        let (ex, _) = setup();
        assert!(ex.decode_round(8192) > ex.decode_round(1024));
    }
}
