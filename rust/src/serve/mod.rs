//! The serving coordinator (L3): waiting queue, Algorithm-1 scheduler,
//! queue-based prefetcher, virtual-time engine over all the paper's
//! system variants, metrics, and the real-path HTTP server.

pub mod batcher;
pub mod engine;
pub mod executor;
pub mod metrics;
pub mod prefetcher;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod system;
pub mod workload;
pub mod server;
