//! Algorithm 1 (`PCR_step`) pieces: look-ahead updates from the waiting
//! queue and the per-request data-movement plan (which chunks come from
//! GPU / DRAM / SSD, which must be computed).

use crate::cache::chunk::ChunkedSeq;
use crate::cache::engine::CacheEngine;
use crate::cache::prefix_tree::NodeId;
use crate::cache::tier::Tier;

/// The movement plan for one scheduled request (Algorithm 1's
/// `cpu_to_gpu` / `ssd_to_gpu` / `gpu_to_cpu` sets plus token math).
#[derive(Clone, Debug, Default)]
pub struct MovementPlan {
    /// Matched prefix nodes in chain order.
    pub matched: Vec<NodeId>,
    /// Chunks already resident on GPU (no transfer needed).
    pub from_gpu: usize,
    /// Chunks to upload from DRAM (`cpu_to_gpu`).
    pub from_dram: usize,
    /// Chunks that must first be read from SSD (`ssd_to_gpu`).
    pub from_ssd: usize,
    /// SSD-resident matched nodes (the demand-load set).
    pub ssd_nodes: Vec<NodeId>,
    /// Tokens covered by the matched prefix.
    pub reused_tokens: usize,
    /// Tokens that must be computed (`AdjustTokens`).
    pub computed_tokens: usize,
    /// Full chunks among the computed tokens (these get cached; the
    /// tail is not chunk-aligned and is never cached).
    pub computed_chunks: usize,
}

impl MovementPlan {
    pub fn matched_chunks(&self) -> usize {
        self.matched.len()
    }
}

/// Match `chain` against the cache and derive the movement plan.
/// Matched nodes are *pinned* — callers must `unpin_plan` after the
/// step so in-use chunks cannot be evicted mid-flight.
pub fn plan_movement(cache: &mut CacheEngine, chain: &ChunkedSeq) -> MovementPlan {
    let lookup = cache.lookup(&chain.keys);
    let mut plan = MovementPlan::default();
    for (id, tier) in lookup.nodes.iter().zip(&lookup.tiers) {
        match tier {
            Tier::Gpu => plan.from_gpu += 1,
            Tier::Dram => plan.from_dram += 1,
            Tier::Ssd => {
                plan.from_ssd += 1;
                plan.ssd_nodes.push(*id);
            }
        }
        cache.tree.pin(*id);
        plan.matched.push(*id);
    }
    plan.reused_tokens = chain.tokens_in(plan.matched.len());
    plan.computed_tokens = chain.total_tokens - plan.reused_tokens;
    plan.computed_chunks = chain.n_chunks() - plan.matched.len();
    plan
}

/// Release the pins taken by [`plan_movement`].
pub fn unpin_plan(cache: &mut CacheEngine, plan: &MovementPlan) {
    for id in &plan.matched {
        cache.tree.unpin(*id);
    }
}

/// Look-ahead update (Algorithm 1's prefetch-hint loop, reverse order):
/// protect every queued request's matched chunks from eviction for
/// `horizon` clock ticks. Returns the number of protected chunks.
pub fn apply_lookahead<'a>(
    cache: &mut CacheEngine,
    window_chains: impl Iterator<Item = &'a ChunkedSeq>,
    horizon: u64,
) -> usize {
    let mut protected = 0;
    for chain in window_chains {
        protected += cache.boost_chain(&chain.keys, horizon);
    }
    protected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::engine::CacheConfig;

    const CB: u64 = 1000; // bytes per chunk in these tests

    fn engine() -> CacheEngine {
        CacheEngine::new(CacheConfig {
            chunk_tokens: 4,
            gpu_capacity: 100 * CB,
            dram_capacity: 100 * CB,
            ssd_capacity: 100 * CB,
            policy: "lookahead-lru".into(),
        })
    }

    fn chain(tag: u32, chunks: usize, tail: usize) -> ChunkedSeq {
        let tokens: Vec<u32> = (0..(chunks * 4 + tail) as u32)
            .map(|i| i.wrapping_mul(31).wrapping_add(tag * 1_000_003))
            .collect();
        ChunkedSeq::new(&tokens, 4)
    }

    fn insert(cache: &mut CacheEngine, c: &ChunkedSeq, n: usize, tier: Tier) {
        let mut parent = None;
        for key in c.keys.iter().take(n) {
            parent = cache.insert(parent, *key, CB, tier);
            assert!(parent.is_some());
        }
    }

    #[test]
    fn plan_counts_by_tier() {
        let mut cache = engine();
        let c = chain(1, 5, 2);
        // chunks 0,1 in GPU; 2 in DRAM; 3 on SSD; 4 missing
        insert(&mut cache, &c, 4, Tier::Ssd);
        let ids: Vec<NodeId> = c.keys.iter().take(4)
            .map(|k| cache.tree.get(*k).unwrap()).collect();
        cache.promote(ids[0], Tier::Gpu);
        cache.promote(ids[1], Tier::Gpu);
        cache.promote(ids[2], Tier::Dram);
        let plan = plan_movement(&mut cache, &c);
        assert_eq!(plan.matched_chunks(), 4);
        assert_eq!(plan.from_gpu, 2);
        assert_eq!(plan.from_dram, 1);
        assert_eq!(plan.from_ssd, 1);
        assert_eq!(plan.ssd_nodes, vec![ids[3]]);
        assert_eq!(plan.reused_tokens, 16);
        assert_eq!(plan.computed_tokens, 4 + 2); // chunk 4 + tail
        assert_eq!(plan.computed_chunks, 1);
        // matched nodes are pinned
        for id in &plan.matched {
            assert!(cache.tree.node(*id).pins > 0);
        }
        unpin_plan(&mut cache, &plan);
        for id in &plan.matched {
            assert_eq!(cache.tree.node(*id).pins, 0);
        }
    }

    #[test]
    fn empty_cache_plans_full_compute() {
        let mut cache = engine();
        let c = chain(2, 3, 1);
        let plan = plan_movement(&mut cache, &c);
        assert_eq!(plan.matched_chunks(), 0);
        assert_eq!(plan.computed_tokens, 13);
        assert_eq!(plan.computed_chunks, 3);
    }

    #[test]
    fn lookahead_protects_window_chains() {
        let mut cache = engine();
        let a = chain(3, 2, 0);
        let b = chain(4, 2, 0);
        insert(&mut cache, &a, 2, Tier::Dram);
        insert(&mut cache, &b, 2, Tier::Dram);
        let protected = apply_lookahead(&mut cache, [&a].into_iter(), 50);
        assert_eq!(protected, 2);
        let now = cache.tree.now();
        for k in &a.keys {
            let id = cache.tree.get(*k).unwrap();
            assert!(cache.tree.node(id).boost_until > now);
        }
        for k in &b.keys {
            let id = cache.tree.get(*k).unwrap();
            assert_eq!(cache.tree.node(id).boost_until, 0);
        }
    }
}
