//! Request lifecycle: one RAG query moving through retrieval, the
//! waiting queue, prefill, and decode — with every timestamp the
//! paper's metrics need (TTFT, E2EL, ITL, queueing vs computing).

use crate::cache::chunk::ChunkedSeq;
use std::sync::Arc;

/// Where a request currently is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// Retrieval done, waiting in the scheduler queue.
    Waiting,
    /// Prefill executed; decoding output tokens.
    Decoding,
    /// All output tokens produced.
    Finished,
}

/// One in-flight request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Which distinct dataset input this request replays (workload
    /// sampling repeats inputs — that is where prefix reuse comes from).
    pub input_id: u32,
    /// Full LLM input `[docs ‖ query]`. A shared slice — one
    /// allocation per distinct workload input, refcounted across
    /// repeats and admissions (no per-request token copies).
    pub tokens: Arc<[u32]>,
    /// Chunked view with prefix-chain keys.
    pub chain: Arc<ChunkedSeq>,
    pub output_tokens: usize,

    pub state: RequestState,
    /// Seconds (virtual or wall) — absolute times.
    pub arrival: f64,
    /// When retrieval finished and the request entered the queue.
    pub queued_at: f64,
    /// When prefill started.
    pub started_at: Option<f64>,
    /// When the first output token was produced (prefill end).
    pub first_token_at: Option<f64>,
    pub finished_at: Option<f64>,
    /// Inter-token latency samples (decode gaps).
    pub itl: Vec<f64>,
    /// Decode progress.
    pub generated: usize,

    /// Matched-prefix length (chunks) the cluster router's directory
    /// predicted for the replica this request was placed on. `None` on
    /// the single-engine path. Prefill compares it against the actual
    /// local match to count directory staleness.
    pub routed_matched: Option<usize>,

    // --- reuse accounting (filled at prefill) ---
    pub reused_tokens: usize,
    pub computed_tokens: usize,
    pub reused_from_gpu: usize,
    pub reused_from_dram: usize,
    pub reused_from_ssd: usize,
}

impl Request {
    pub fn new(
        id: u64,
        input_id: u32,
        tokens: Arc<[u32]>,
        chain: Arc<ChunkedSeq>,
        output_tokens: usize,
        arrival: f64,
        queued_at: f64,
    ) -> Request {
        Request {
            id,
            input_id,
            tokens,
            chain,
            output_tokens,
            state: RequestState::Waiting,
            arrival,
            queued_at,
            started_at: None,
            first_token_at: None,
            finished_at: None,
            itl: Vec::new(),
            generated: 0,
            routed_matched: None,
            reused_tokens: 0,
            computed_tokens: 0,
            reused_from_gpu: 0,
            reused_from_dram: 0,
            reused_from_ssd: 0,
        }
    }

    /// Time To First Token (the paper's headline metric).
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// End-to-end latency.
    pub fn e2el(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.arrival)
    }

    /// Queueing time (Fig 11's contrast with computing time).
    pub fn queue_time(&self) -> Option<f64> {
        self.started_at.map(|t| t - self.queued_at)
    }

    /// Prefill wall time.
    pub fn compute_time(&self) -> Option<f64> {
        match (self.started_at, self.first_token_at) {
            (Some(s), Some(f)) => Some(f - s),
            _ => None,
        }
    }

    pub fn total_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Roll the request back to freshly-queued so it can be re-routed
    /// after a replica failure. Arrival and queue timestamps survive —
    /// TTFT/E2EL keep charging from the original admission, so a
    /// failover shows up as latency, never as lost work.
    pub fn reset_for_retry(&mut self) {
        self.state = RequestState::Waiting;
        self.started_at = None;
        self.first_token_at = None;
        self.finished_at = None;
        self.itl.clear();
        self.generated = 0;
        self.routed_matched = None;
        self.reused_tokens = 0;
        self.computed_tokens = 0;
        self.reused_from_gpu = 0;
        self.reused_from_dram = 0;
        self.reused_from_ssd = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::ChunkedSeq;

    fn req() -> Request {
        let tokens: Vec<u32> = (0..1000).collect();
        let chain = ChunkedSeq::new(&tokens, 256);
        Request::new(1, 0, tokens.into(), Arc::new(chain), 16, 10.0, 10.2)
    }

    #[test]
    fn metric_derivations() {
        let mut r = req();
        assert_eq!(r.ttft(), None);
        r.started_at = Some(11.0);
        r.first_token_at = Some(12.5);
        r.finished_at = Some(13.0);
        assert!((r.ttft().unwrap() - 2.5).abs() < 1e-12);
        assert!((r.e2el().unwrap() - 3.0).abs() < 1e-12);
        assert!((r.queue_time().unwrap() - 0.8).abs() < 1e-12);
        assert!((r.compute_time().unwrap() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn reset_for_retry_keeps_admission_times() {
        let mut r = req();
        r.state = RequestState::Decoding;
        r.started_at = Some(11.0);
        r.first_token_at = Some(12.5);
        r.itl = vec![0.02; 5];
        r.generated = 6;
        r.reused_tokens = 512;
        r.routed_matched = Some(2);
        r.reset_for_retry();
        assert_eq!(r.state, RequestState::Waiting);
        assert_eq!(r.ttft(), None);
        assert!(r.itl.is_empty());
        assert_eq!(r.generated, 0);
        assert_eq!(r.reused_tokens, 0);
        assert_eq!(r.routed_matched, None);
        // latency still charges from the original admission
        assert_eq!(r.arrival, 10.0);
        assert_eq!(r.queued_at, 10.2);
    }

    #[test]
    fn chain_matches_tokens() {
        let r = req();
        assert_eq!(r.chain.n_chunks(), 3); // 1000 / 256
        assert_eq!(r.chain.tail_tokens, 1000 - 768);
        assert_eq!(r.total_tokens(), 1000);
    }
}
