//! The serving engine: a virtual-time replay of the full PCR loop —
//! Poisson arrivals → retrieval → waiting queue → Algorithm 1 step
//! (look-ahead updates, prefetch submission, movement planning,
//! layer-wise pipelined prefill, async write-back) → fused decode.
//!
//! Every baseline of the paper runs through this same engine with a
//! different [`SystemSpec`]; only tier availability, overlap mode,
//! prefetch window, and eviction policy change — mirroring the paper's
//! "all methods share vLLM as their common backbone".

use crate::cache::engine::{CacheConfig, CacheEngine, CacheStats};
use crate::cache::prefetch;
use crate::cache::prefix_tree::NodeId;
use crate::cache::tier::Tier;
use crate::config::ExperimentConfig;
use crate::hw::spec::{model_spec, platform_spec, ModelSpec, PlatformSpec};
use crate::hw::transfer::TransferFabric;
use crate::io::fault::{FaultSession, Injected, Transient};
use crate::io::{IoStats, Lane, VirtualLanes};
use crate::obs::breakdown::{RequestBreakdown, TtftAttribution};
use crate::obs::timeline::{FlightRecorder, FlightSnapshot, TimelineSample, TimelineSampler};
use crate::obs::timeline::{REASON_DEGRADE, REASON_FAILOVER};
use crate::obs::trace::{Kind, Phase, TraceEvent, Tracer, Track};
use crate::serve::executor::SimExecutor;
use crate::serve::metrics::{MetricsCollector, Report};
use crate::serve::prefetcher::SimPrefetcher;
use crate::serve::queue::WaitingQueue;
use crate::serve::request::{Request, RequestState};
use crate::serve::scheduler::{apply_lookahead, plan_movement, unpin_plan};
use crate::serve::system::SystemSpec;
use crate::serve::workload::Workload;
use std::sync::Arc;

/// Aggregate time breakdown of one run (seconds of engine activity).
#[derive(Clone, Copy, Debug, Default)]
pub struct RunBreakdown {
    pub ssd_wait: f64,
    pub pipeline: f64,
    pub compute: f64,
    pub upload: f64,
    pub offload: f64,
    pub decode: f64,
}

/// Everything a bench needs from one serving run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub system: &'static str,
    pub report: Report,
    pub cache: CacheStats,
    pub breakdown: RunBreakdown,
    /// Virtual time at which the last request finished.
    pub virtual_duration: f64,
    pub prefetch_submitted: u64,
    pub prefetch_completed: u64,
    pub prefetch_dropped: u64,
    pub prefetch_cancelled: u64,
    /// Dual-lane transfer counters for the SSD read resource.
    pub io: IoStats,
    /// Faults injected by the harness (all zero without a fault plan).
    pub injected: Injected,
    /// Mean chunks reused per tier per request.
    pub reused_gpu_chunks: u64,
    pub reused_dram_chunks: u64,
    pub reused_ssd_chunks: u64,
    /// Recorded trace events (empty unless `obs.trace` is on).
    pub trace: Vec<TraceEvent>,
    /// Events the bounded trace ring had to discard.
    pub trace_dropped: u64,
    /// Periodic gauge samples (empty unless `obs.timeline` is on).
    pub timeline: Vec<TimelineSample>,
    /// Flight-recorder snapshots taken on degrade/failover triggers.
    pub flight: Vec<FlightSnapshot>,
    /// Per-prefill TTFT attribution rows (always recorded).
    pub attribution: TtftAttribution,
}

/// Derive the cache geometry for (config, system, model, platform).
pub fn cache_config(
    cfg: &ExperimentConfig,
    spec: &SystemSpec,
    model: &ModelSpec,
    platform: &PlatformSpec,
) -> CacheConfig {
    let dram_default = (platform.cpu_mem_bytes as f64 * 0.8) as u64;
    let ssd_default = (platform.ssd_bytes as f64 * 0.5) as u64;
    CacheConfig {
        chunk_tokens: cfg.chunk_tokens,
        gpu_capacity: if cfg.gpu_bytes > 0 {
            cfg.gpu_bytes
        } else {
            platform.gpu_kv_budget(model)
        },
        dram_capacity: if spec.dram_tier {
            if cfg.dram_bytes > 0 { cfg.dram_bytes } else { dram_default }
        } else {
            0
        },
        ssd_capacity: if spec.ssd_tier {
            if cfg.ssd_bytes > 0 { cfg.ssd_bytes } else { ssd_default }
        } else {
            0
        },
        policy: spec.policy.clone(),
    }
}

/// One serving-engine instance with all of its run state: cache,
/// transfer fabric, dual-lane SSD view, executor, prefetcher, queues,
/// metrics, and the virtual clock. [`run`] drives one of these over a
/// whole workload; `cluster::Replica` holds one per replica and
/// interleaves [`step`](EngineCore::step) calls across the fleet.
///
/// The admission policy (which requests enter [`waiting`]
/// (EngineCore::waiting), and when) is deliberately *outside* this
/// struct — single-engine ingest and cluster routing are both callers
/// of [`enqueue`](EngineCore::enqueue).
pub struct EngineCore {
    /// The system variant this engine emulates.
    pub spec: SystemSpec,
    /// Replica-local multi-tier cache (public so the cluster layer can
    /// enable residency-event tracking and read `stats`).
    pub cache: CacheEngine,
    fabric: TransferFabric,
    lanes: VirtualLanes,
    exec: SimExecutor,
    prefetcher: SimPrefetcher,
    strategy: Box<dyn prefetch::PrefetchStrategy>,
    pub metrics: MetricsCollector,
    pub breakdown: RunBreakdown,
    /// Requests admitted but not yet prefetched/prefilled.
    pub waiting: WaitingQueue,
    decoding: Vec<Request>,
    /// This engine's virtual clock (seconds).
    pub clock: f64,
    /// Requests routed here with a directory-predicted matched-prefix
    /// length the local tree could no longer honor at prefill time
    /// (eviction raced the routing decision). Always 0 single-engine.
    pub directory_stale: u64,
    chunk_bytes: u64,
    boost_horizon: u64,
    lookahead_window: usize,
    io_prefetch_depth: usize,
    reused_gpu: u64,
    reused_dram: u64,
    reused_ssd: u64,
    /// Seeded fault-injection session (None on healthy runs — the
    /// entire degradation path is then a strict no-op).
    faults: Option<FaultSession>,
    /// Virtual retry budget for transient SSD read errors (mirrors
    /// `IoConfig::retries` on the real path).
    io_retry_limit: u32,
    /// Span/event recorder (null sink unless `obs.trace` is on; the
    /// cluster layer emits routing events through it, so it is pub).
    pub tracer: Tracer,
    /// Periodic gauge sampler (None unless `obs.timeline` is on).
    timeline: Option<TimelineSampler>,
    /// Last-N event snapshots on degrade/failover (needs tracing on).
    flight: Option<FlightRecorder>,
}

impl EngineCore {
    /// Build one engine for `cfg` × `spec`. `mean_input_tokens` comes
    /// from the workload — it sizes the look-ahead boost horizon.
    pub fn new(cfg: &ExperimentConfig, spec: &SystemSpec, mean_input_tokens: f64) -> EngineCore {
        let model = model_spec(&cfg.model).expect("validated model");
        let platform = platform_spec(&cfg.platform).expect("validated platform");
        let mut cache = CacheEngine::new(cache_config(cfg, spec, &model, &platform));
        // Victim selection path: incremental index (default) or the
        // fused O(n) scan (`cache.indexed_eviction = false` — the A/B
        // knob the eviction-pressure bench and replay-parity test flip).
        cache.use_indexed_eviction = cfg.indexed_eviction;
        let tracer = if cfg.obs_trace {
            Tracer::ring(cfg.obs_trace_capacity)
        } else {
            Tracer::off()
        };
        // cache residency events only buffer when tracing is on — the
        // disabled path stays one `Option` check per hook site
        if tracer.enabled() {
            cache.obs = Some(Vec::new());
        }
        let fabric = TransferFabric::new(&platform);
        // Dual-lane virtual-time view of the SSD read resource: demand
        // reads preempt queued prefetch work for async-I/O systems; for
        // synchronous systems both classes share the prefetch-lane
        // FIFO, reproducing the single shared channel they model.
        let lanes = VirtualLanes::from_channel(&fabric.ssd_read);
        let exec = SimExecutor::new(&model, &platform, cfg.chunk_tokens);
        let strategy = prefetch::registry::parse(&spec.prefetch_strategy).unwrap_or_else(|| {
            panic!(
                "unknown prefetch strategy '{}' (registered: {})",
                spec.prefetch_strategy,
                prefetch::registry::names_joined()
            )
        });
        let chunk_bytes = model.kv_bytes_per_token() * cfg.chunk_tokens as u64;
        // Look-ahead LRU protection horizon in tree-clock ticks:
        // roughly the touches one request generates times window depth.
        let boost_horizon = (cfg.lookahead_window.max(1)
            * (mean_input_tokens as usize / cfg.chunk_tokens + 2)
            * 4) as u64;
        EngineCore {
            spec: spec.clone(),
            cache,
            fabric,
            lanes,
            exec,
            prefetcher: SimPrefetcher::new(),
            strategy,
            metrics: MetricsCollector::new(),
            breakdown: RunBreakdown::default(),
            waiting: WaitingQueue::new(),
            decoding: Vec::new(),
            clock: 0.0,
            directory_stale: 0,
            chunk_bytes,
            boost_horizon,
            lookahead_window: cfg.lookahead_window,
            io_prefetch_depth: cfg.io_prefetch_depth,
            reused_gpu: 0,
            reused_dram: 0,
            reused_ssd: 0,
            faults: cfg
                .fault_plan()
                .filter(|p| p.enabled())
                .map(FaultSession::new),
            io_retry_limit: cfg.io_retries,
            timeline: cfg.obs_timeline.then(|| TimelineSampler::new(cfg.obs_timeline_interval)),
            flight: (cfg.obs_trace && cfg.obs_flight_depth > 0)
                .then(|| FlightRecorder::new(cfg.obs_flight_depth)),
            tracer,
        }
    }

    /// Admit a request whose retrieval has completed.
    pub fn enqueue(&mut self, req: Request) {
        let (id, arrival, queued_at) = (req.id, req.arrival, req.queued_at);
        self.tracer.emit(|| TraceEvent {
            t: arrival,
            track: Track::Engine,
            kind: Kind::Retrieval,
            id,
            phase: Phase::Begin,
        });
        self.tracer.emit(|| TraceEvent {
            t: queued_at,
            track: Track::Engine,
            kind: Kind::Retrieval,
            id,
            phase: Phase::End,
        });
        self.tracer.emit(|| TraceEvent {
            t: queued_at,
            track: Track::Engine,
            kind: Kind::Queue,
            id,
            phase: Phase::Begin,
        });
        self.waiting.push(req);
    }

    /// True when nothing is queued or decoding — the engine can only
    /// advance by having its clock jumped to the next admission.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.decoding.is_empty()
    }

    /// Requests mid-decode.
    pub fn decoding_len(&self) -> usize {
        self.decoding.len()
    }

    /// Open requests (queued + decoding) — the router's load signal.
    pub fn load(&self) -> usize {
        self.waiting.len() + self.decoding.len()
    }

    /// Take every open request (queued then decoding) out of the
    /// engine, reset to freshly-queued — the failover path: a dying
    /// replica's work is evacuated for re-routing. Its cache and
    /// metrics stay as they were at the moment of failure.
    pub fn evacuate(&mut self) -> Vec<Request> {
        let mut out = self.waiting.drain_all();
        out.append(&mut self.decoding);
        for r in &mut out {
            r.reset_for_retry();
        }
        let clock = self.clock;
        for r in &out {
            let id = r.id;
            self.tracer.emit(|| TraceEvent {
                t: clock,
                track: Track::Router,
                kind: Kind::Failover,
                id,
                phase: Phase::Instant,
            });
        }
        if self.tracer.enabled() {
            if let Some(fr) = self.flight.as_mut() {
                let depth = fr.depth;
                fr.snapshot(clock, REASON_FAILOVER, self.tracer.recent(depth));
            }
        }
        out
    }

    /// One engine pass: look-ahead hints + prefetch submission, then
    /// either the head request's prefill (with fused decode progress
    /// and write-back) or a pure decode round. Advances [`clock`]
    /// (EngineCore::clock). Call only when not [`is_idle`]
    /// (EngineCore::is_idle) — an idle step would spin a zero-length
    /// decode round.
    pub fn step(&mut self) {
        let clock = self.clock;

        // 0. periodic telemetry sample (virtual-time cadence)
        if let Some(tl) = self.timeline.as_mut() {
            if tl.due(clock) {
                let hits = self.cache.stats.total_hits();
                let missed = self.cache.stats.missed_chunks;
                let hit_ratio_window = tl.windowed_hit_ratio(hits, missed);
                tl.push(TimelineSample {
                    t: clock,
                    gpu_bytes: self.cache.used(Tier::Gpu),
                    dram_bytes: self.cache.used(Tier::Dram),
                    ssd_bytes: self.cache.used(Tier::Ssd),
                    queue_depth: self.waiting.len(),
                    decoding: self.decoding.len(),
                    inflight_prefetch: self.prefetcher.inflight_count(),
                    hit_ratio_window,
                });
            }
        }

        // 1. Algorithm 1 prefetch-hint loop over the look-ahead window,
        // in reverse order (soonest-served request gets the freshest
        // protection and its loads are queued... see queue.rs).
        if self.spec.lookahead_lru {
            let chains = self
                .waiting
                .window(self.lookahead_window)
                .map(|r| r.chain.as_ref())
                .collect::<Vec<_>>();
            apply_lookahead(&mut self.cache, chains.into_iter().rev(), self.boost_horizon);
        }
        if self.spec.prefetch_window > 0 && self.spec.ssd_tier {
            let targets = {
                let window: Vec<&crate::cache::chunk::ChunkedSeq> = self
                    .waiting
                    .window(self.spec.prefetch_window)
                    .map(|r| r.chain.as_ref())
                    .collect();
                self.strategy.select_targets(&window, &self.cache)
            };
            self.prefetcher.submit_targets(
                &self.cache,
                &mut self.lanes,
                clock,
                &targets,
                self.io_prefetch_depth,
                &mut self.tracer,
            );
        }
        // drop queued loads whose target was evicted or promoted since
        // submission (the engine's cancellation tokens, in virtual time)
        self.prefetcher
            .cancel_stale(&self.cache, &mut self.lanes, clock, &mut self.tracer);
        self.prefetcher
            .drain(&mut self.cache, &mut self.lanes, clock, &mut self.tracer);

        // 2. serve the head request's prefill (one pass), or a decode
        // round if nothing is waiting.
        if let Some(mut req) = self.waiting.pop() {
            req.started_at = Some(clock);
            let req_id = req.id;
            self.tracer.emit(|| TraceEvent {
                t: clock,
                track: Track::Engine,
                kind: Kind::Queue,
                id: req_id,
                phase: Phase::End,
            });
            let mut plan = plan_movement(&mut self.cache, &req.chain);
            if let Some(predicted) = req.routed_matched {
                // the cluster directory promised `predicted` matched
                // chunks when this request was placed; anything shorter
                // means residency changed in between
                if plan.matched.len() < predicted {
                    self.directory_stale += 1;
                }
            }

            // fault-injection pre-pass (virtual-time twin of the real
            // read path's degradation): decide per demand SSD load
            // whether it is lost, corrupted, flaky, or spiked *before*
            // booking transfers. Recoverable faults only add latency;
            // an unreadable chunk is quarantined and the plan
            // recomputed, so the request serves the shortened matched
            // prefix and recomputes the rest — output unchanged.
            let mut load_extra: Vec<(NodeId, f64)> = Vec::new();
            if let Some(fs) = self.faults.clone() {
                let mut cut = None;
                for &id in &plan.ssd_nodes {
                    let key = self.cache.tree.node(id).key;
                    // lost checked first: a vanished copy can't also
                    // fail its checksum
                    if fs.lost(key) || fs.corrupted(key) {
                        cut = Some(id);
                        break;
                    }
                    let mut extra = 0.0;
                    match fs.transient(key, self.io_retry_limit) {
                        Transient::Clean => {}
                        Transient::Recovered(n) => {
                            self.metrics.degrade.retries += n as u64;
                            self.lanes.stats.demand.retries += n as u64;
                            let bytes = self.cache.tree.node(id).bytes;
                            extra += n as f64 * self.lanes.copy_time(bytes);
                        }
                        Transient::Exhausted(n) => {
                            self.metrics.degrade.retries += n as u64;
                            self.lanes.stats.demand.retries += n as u64;
                            cut = Some(id);
                            break;
                        }
                    }
                    if fs.spiked(key) {
                        extra += fs.plan().spike_seconds;
                    }
                    if extra > 0.0 {
                        load_extra.push((id, extra));
                    }
                }
                if let Some(cid) = cut {
                    self.metrics.degrade.degraded_loads += 1;
                    self.metrics.degrade.quarantined_chunks += 1;
                    // release the plan's pins, drop the unreadable
                    // chunk (and its now-unreachable resident subtree),
                    // then re-plan: the new plan matches only the
                    // prefix before the cut, whose load decisions above
                    // all came back readable
                    unpin_plan(&mut self.cache, &plan);
                    self.cache.quarantine(cid);
                    plan = plan_movement(&mut self.cache, &req.chain);
                    load_extra.retain(|(id, _)| plan.ssd_nodes.contains(id));
                    self.tracer.emit(|| TraceEvent {
                        t: clock,
                        track: Track::Engine,
                        kind: Kind::FaultPrepass,
                        id: req_id,
                        phase: Phase::Instant,
                    });
                    // a degrade counter fired: snapshot the event tail
                    if self.tracer.enabled() {
                        self.drain_cache_obs();
                        if let Some(fr) = self.flight.as_mut() {
                            let depth = fr.depth;
                            fr.snapshot(clock, REASON_DEGRADE, self.tracer.recent(depth));
                        }
                    }
                } else if !load_extra.is_empty() {
                    self.tracer.emit(|| TraceEvent {
                        t: clock,
                        track: Track::Engine,
                        kind: Kind::FaultPrepass,
                        id: req_id,
                        phase: Phase::Instant,
                    });
                }
            }

            // demand SSD loads: in-flight prefetches are claimed (an
            // async system upgrades queued ones to demand priority —
            // read once, served sooner), the rest are enqueued on the
            // demand lane; without async I/O, demand reads take the
            // same FIFO the prefetch traffic uses, so a prefetch
            // backlog delays them — the contention PCR removes.
            let mut ssd_ready = clock;
            for id in &plan.ssd_nodes {
                let node_id = id.0 as u64;
                let t = if self.spec.async_io {
                    match self.prefetcher.upgrade(
                        &self.cache,
                        &mut self.lanes,
                        clock,
                        *id,
                        &mut self.tracer,
                    ) {
                        Some(t) => t,
                        None => {
                            let bytes = self.cache.tree.node(*id).bytes;
                            let (s, f) = self.lanes.enqueue(Lane::Demand, clock, bytes);
                            self.lanes.stats.demand.completed += 1;
                            self.tracer.emit(|| TraceEvent {
                                t: s,
                                track: Track::LaneDemand,
                                kind: Kind::KvLoad,
                                id: node_id,
                                phase: Phase::Complete(f - s),
                            });
                            f
                        }
                    }
                } else {
                    match self.prefetcher.ready_at(*id) {
                        Some(t) => t,
                        None => {
                            let bytes = self.cache.tree.node(*id).bytes;
                            // shared-FIFO timing, booked as demand work
                            let (s, f) = self.lanes.reserve(Lane::Prefetch, clock, bytes);
                            let st = &mut self.lanes.stats.demand;
                            st.submitted += 1;
                            st.completed += 1;
                            st.bytes_moved += bytes;
                            st.wait_seconds += s - clock;
                            st.serve_seconds += f - s;
                            self.tracer.emit(|| TraceEvent {
                                t: s,
                                track: Track::LaneDemand,
                                kind: Kind::KvLoad,
                                id: node_id,
                                phase: Phase::Complete(f - s),
                            });
                            f
                        }
                    }
                };
                // injected retry/spike latency for this load, if any
                let extra = load_extra
                    .iter()
                    .find(|(n, _)| n == id)
                    .map_or(0.0, |(_, e)| *e);
                ssd_ready = ssd_ready.max(t + extra);
            }

            let step =
                self.exec
                    .prefill_step(clock, ssd_ready, &plan, &self.spec, &mut self.fabric);
            let dur = step.total();
            self.breakdown.ssd_wait += step.ssd_wait;
            self.breakdown.pipeline += step.pipeline;
            self.breakdown.compute += step.compute;
            self.breakdown.upload += step.upload;
            self.breakdown.offload += step.offload;

            // fused decode progress for running requests (chunked-
            // prefill interleaving): each decoding request advances
            // ~dur/decode_round tokens during this pass
            advance_decodes(
                &mut self.decoding,
                &self.exec,
                dur,
                clock,
                &mut self.metrics,
                &mut self.breakdown,
            );

            self.clock += dur;
            req.first_token_at = Some(self.clock);
            req.generated = 1;
            self.tracer.emit(|| TraceEvent {
                t: clock,
                track: Track::Engine,
                kind: Kind::Prefill,
                id: req_id,
                phase: Phase::Complete(dur),
            });
            // TTFT attribution: the stages sum to this attempt's TTFT
            // exactly — `dur = ssd_wait + pipeline` and the span from
            // arrival to first token telescopes through queued_at and
            // started_at (= `clock`). `hidden` is the transfer time the
            // layer-wise overlap absorbed; it never reached TTFT, so it
            // is reported but excluded from the reconciling sum.
            let exposed = step.pipeline - step.compute;
            self.metrics.attribution.record(RequestBreakdown {
                request: req_id,
                retrieval: req.queued_at - req.arrival,
                queue: clock - req.queued_at,
                load_stall: step.ssd_wait,
                compute: step.compute,
                exposed,
                hidden: (step.upload + step.offload - exposed).max(0.0),
                ttft: self.clock - req.arrival,
            });
            req.reused_tokens = plan.reused_tokens;
            req.computed_tokens = plan.computed_tokens;
            req.reused_from_gpu = plan.from_gpu;
            req.reused_from_dram = plan.from_dram;
            req.reused_from_ssd = plan.from_ssd;
            self.reused_gpu += plan.from_gpu as u64;
            self.reused_dram += plan.from_dram as u64;
            self.reused_ssd += plan.from_ssd as u64;

            // 3. write-back: matched chunks promote to GPU; computed
            // chunks are inserted GPU + DRAM (+ SSD metadata, async
            // write on the ssd_write channel)
            let mut pinned_new = Vec::new();
            let mut parent = None;
            for (i, key) in req.chain.keys.iter().enumerate() {
                if i < plan.matched.len() {
                    let id = plan.matched[i];
                    self.cache.promote(id, Tier::Gpu); // best effort
                    parent = Some(id);
                    continue;
                }
                // newly computed chunk
                let mut id = self.cache.insert(parent, *key, self.chunk_bytes, Tier::Gpu);
                if self.spec.dram_tier {
                    let dram_id = self.cache.insert(parent, *key, self.chunk_bytes, Tier::Dram);
                    id = id.or(dram_id);
                }
                if self.spec.ssd_tier {
                    let ssd_id = self.cache.insert(parent, *key, self.chunk_bytes, Tier::Ssd);
                    if ssd_id.is_some() {
                        // async write-back; never blocks the next step
                        self.fabric.ssd_write.enqueue(self.clock, self.chunk_bytes);
                    }
                    id = id.or(ssd_id);
                }
                match id {
                    Some(id) => {
                        self.cache.tree.pin(id);
                        pinned_new.push(id);
                        parent = Some(id);
                    }
                    None => break, // no tier could hold it: stop chaining
                }
            }
            unpin_plan(&mut self.cache, &plan);
            for id in pinned_new {
                self.cache.tree.unpin(id);
            }

            if req.generated >= req.output_tokens {
                req.state = RequestState::Finished;
                req.finished_at = Some(self.clock);
                self.metrics.record(&req);
            } else {
                req.state = RequestState::Decoding;
                self.decoding.push(req);
            }
        } else {
            // pure decode round: whole batch advances one token
            let ctx = self
                .decoding
                .iter()
                .map(|r| (r.total_tokens() + r.generated) as u64)
                .max()
                .unwrap_or(0);
            let dt = self.exec.decode_round(ctx);
            self.clock += dt;
            self.breakdown.decode += dt;
            let batch = self.decoding.len() as u64;
            self.tracer.emit(|| TraceEvent {
                t: clock,
                track: Track::Engine,
                kind: Kind::DecodeRound,
                id: batch,
                phase: Phase::Complete(dt),
            });
            for r in self.decoding.iter_mut() {
                r.generated += 1;
                r.itl.push(dt);
            }
            retire_finished(&mut self.decoding, self.clock, &mut self.metrics);
        }

        // forward cache residency events buffered during this step,
        // stamped with the post-step clock (strict no-op when off)
        self.drain_cache_obs();
    }

    /// Move the cache's buffered residency events into the trace,
    /// stamping them with the current virtual clock. The buffer only
    /// exists while tracing is on.
    fn drain_cache_obs(&mut self) {
        if let Some(buf) = self.cache.obs.as_mut() {
            let t = self.clock;
            for mut ev in buf.drain(..) {
                ev.t = t;
                self.tracer.emit(|| ev);
            }
        }
    }

    /// Finalize: fold the lane counters into the metrics and build the
    /// outcome struct every bench consumes.
    pub fn into_outcome(mut self) -> RunOutcome {
        self.metrics.io = self.lanes.stats;
        let injected = self
            .faults
            .as_ref()
            .map_or(Injected::default(), |f| f.injected());
        self.drain_cache_obs();
        let trace_dropped = self.tracer.dropped();
        let trace = self.tracer.take();
        RunOutcome {
            system: self.spec.name,
            report: self.metrics.report(),
            cache: self.cache.stats,
            breakdown: self.breakdown,
            virtual_duration: self.clock,
            prefetch_submitted: self.prefetcher.submitted,
            prefetch_completed: self.prefetcher.completed,
            prefetch_dropped: self.prefetcher.dropped,
            prefetch_cancelled: self.prefetcher.cancelled,
            io: self.lanes.stats,
            injected,
            reused_gpu_chunks: self.reused_gpu,
            reused_dram_chunks: self.reused_dram,
            reused_ssd_chunks: self.reused_ssd,
            trace,
            trace_dropped,
            timeline: self.timeline.map(|tl| tl.samples).unwrap_or_default(),
            flight: self.flight.map(|fr| fr.snapshots).unwrap_or_default(),
            attribution: self.metrics.attribution.clone(),
        }
    }
}

/// Run one full serving experiment in virtual time: ingest arrivals as
/// their retrieval completes, jump the clock across idle gaps, and
/// [`step`](EngineCore::step) the engine until every request finishes.
pub fn run(cfg: &ExperimentConfig, spec: &SystemSpec, workload: &Workload) -> RunOutcome {
    let mut core = EngineCore::new(cfg, spec, workload.mean_input_tokens);
    let items = &workload.items;
    let mut next = 0usize;

    loop {
        // ingest arrivals whose retrieval has finished by the clock
        while next < items.len()
            && items[next].arrival + items[next].retrieval_seconds <= core.clock
        {
            let it = &items[next];
            core.enqueue(Request::new(
                next as u64,
                it.input_id,
                Arc::clone(&it.tokens),
                Arc::clone(&it.chain),
                cfg.output_tokens,
                it.arrival,
                it.arrival + it.retrieval_seconds,
            ));
            next += 1;
        }
        if core.is_idle() {
            if next < items.len() {
                core.clock = items[next].arrival + items[next].retrieval_seconds;
                continue;
            }
            break;
        }
        core.step();
    }

    debug_assert_eq!(core.metrics.finished, items.len(), "all requests must finish");
    core.into_outcome()
}

/// During a prefill pass of length `dur`, decoding requests advance
/// ~`dur / decode_round` tokens (chunked-prefill fusion).
fn advance_decodes(
    decoding: &mut Vec<Request>,
    exec: &SimExecutor,
    dur: f64,
    clock: f64,
    metrics: &mut MetricsCollector,
    breakdown: &mut RunBreakdown,
) {
    if decoding.is_empty() {
        return;
    }
    let ctx = decoding
        .iter()
        .map(|r| (r.total_tokens() + r.generated) as u64)
        .max()
        .unwrap_or(0);
    let per_tok = exec.decode_round(ctx);
    let steps = (dur / per_tok).floor() as usize;
    if steps == 0 {
        return;
    }
    for r in decoding.iter_mut() {
        let take = steps.min(r.output_tokens - r.generated);
        r.generated += take;
        for _ in 0..take {
            r.itl.push(per_tok);
        }
    }
    breakdown.decode += 0.0; // fused: already inside the prefill pass
    retire_finished(decoding, clock + dur, metrics);
}

fn retire_finished(decoding: &mut Vec<Request>, now: f64, metrics: &mut MetricsCollector) {
    let mut i = 0;
    while i < decoding.len() {
        if decoding[i].generated >= decoding[i].output_tokens {
            let mut r = decoding.swap_remove(i);
            r.state = RequestState::Finished;
            r.finished_at = Some(now);
            metrics.record(&r);
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small but non-trivial workload for engine tests.
    fn test_cfg(system: &str, rate: f64) -> ExperimentConfig {
        ExperimentConfig {
            model: "llama2-7b".into(),
            platform: "a6000".into(),
            system: system.into(),
            n_inputs: 40,
            n_requests: 120,
            oversample: true,
            rate,
            n_docs: 150,
            n_topics: 12,
            mean_doc_tokens: 600,
            query_tokens: 48,
            chunk_tokens: 256,
            // small tiers so eviction/prefetch paths actually trigger:
            // llama2-7b chunks are 256 * 512 KiB = 128 MiB each; the
            // 40-input dataset holds ~200 distinct chunks (~25 GiB)
            gpu_bytes: 2 * (1 << 30),   // ~15 chunks
            dram_bytes: 6 * (1 << 30),  // ~45 chunks
            ssd_bytes: 40 * (1 << 30),  // ~300 chunks (holds everything)
            ..Default::default()
        }
    }

    fn run_system(system: &str, rate: f64) -> RunOutcome {
        let cfg = test_cfg(system, rate);
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named(system, cfg.prefetch_window).unwrap();
        run(&cfg, &spec, &wl)
    }

    #[test]
    fn all_requests_finish_for_every_system() {
        for sys in ["vllm", "ccache", "sccache", "lmcache", "pcr"] {
            let out = run_system(sys, 0.8);
            assert_eq!(out.report.finished, 120, "{sys}");
            assert!(out.report.ttft.mean > 0.0, "{sys}");
            assert!(out.virtual_duration > 0.0, "{sys}");
        }
    }

    #[test]
    fn every_policy_x_strategy_combination_finishes() {
        let cfg = test_cfg("pcr", 0.8);
        let wl = Workload::build(&cfg);
        for (policy, strategy) in [
            ("slru", "queue-window"),
            ("2q", "depth-bounded:2"),
            ("lfuda", "none"),
            ("lookahead-slru", "depth-bounded"),
            ("pgdsf", "queue-window"),
        ] {
            let spec = SystemSpec::named("pcr", cfg.prefetch_window)
                .unwrap()
                .with_overrides(policy, strategy);
            let out = run(&cfg, &spec, &wl);
            assert_eq!(out.report.finished, 120, "{policy} x {strategy}");
            assert!(out.report.ttft.mean > 0.0, "{policy} x {strategy}");
        }
    }

    #[test]
    fn pcr_beats_vllm_on_ttft() {
        let pcr = run_system("pcr", 0.8);
        let vllm = run_system("vllm", 0.8);
        assert!(
            pcr.report.ttft.mean < vllm.report.ttft.mean,
            "pcr {} !< vllm {}",
            pcr.report.ttft.mean,
            vllm.report.ttft.mean
        );
    }

    #[test]
    fn pcr_beats_sync_baselines() {
        let pcr = run_system("pcr", 0.8);
        let scc = run_system("sccache", 0.8);
        assert!(pcr.report.ttft.mean < scc.report.ttft.mean);
    }

    #[test]
    fn tiered_systems_reuse_more_than_vllm() {
        let pcr = run_system("pcr", 0.8);
        let vllm = run_system("vllm", 0.8);
        assert!(pcr.report.mean_reuse_ratio > vllm.report.mean_reuse_ratio);
        assert!(pcr.cache.hit_ratio() > vllm.cache.hit_ratio());
    }

    #[test]
    fn prefetcher_runs_only_for_prefetching_systems() {
        let pcr = run_system("pcr", 0.8);
        let scc = run_system("sccache", 0.8);
        assert_eq!(scc.prefetch_submitted, 0);
        // PCR must actually prefetch under DRAM pressure
        assert!(pcr.prefetch_submitted > 0, "no prefetch traffic");
    }

    #[test]
    fn ttft_grows_with_rate() {
        let low = run_system("pcr", 0.3);
        let high = run_system("pcr", 2.0);
        assert!(high.report.ttft.mean > low.report.ttft.mean);
    }

    #[test]
    fn deterministic_runs() {
        // The engine must replay bit-for-bit on the same workload.
        // (Workload::build itself measures real retrieval wall time, so
        // the workload is built once and shared — as the benches do.)
        let cfg = test_cfg("pcr", 0.8);
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        let a = run(&cfg, &spec, &wl);
        let b = run(&cfg, &spec, &wl);
        assert_eq!(a.report.ttft.mean, b.report.ttft.mean);
        assert_eq!(a.report.e2el.p99, b.report.e2el.p99);
        assert_eq!(a.cache.total_hits(), b.cache.total_hits());
        assert_eq!(a.prefetch_submitted, b.prefetch_submitted);
        assert_eq!(a.io.upgraded, b.io.upgraded);
        assert_eq!(a.io.demand.submitted, b.io.demand.submitted);
    }

    #[test]
    fn indexed_eviction_replays_identically_to_fused_scan() {
        // The indexed victim path must be a pure perf change: a full
        // serving run (eviction pressure, prefetch, pins, boosts) has
        // to land on bit-identical outcomes with the index disabled.
        let mut cfg = test_cfg("pcr", 0.8);
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        assert!(cfg.indexed_eviction, "indexed path must be the default");
        let a = run(&cfg, &spec, &wl);
        cfg.indexed_eviction = false;
        let b = run(&cfg, &spec, &wl);
        assert_eq!(a.report.ttft.mean, b.report.ttft.mean);
        assert_eq!(a.report.e2el.p99, b.report.e2el.p99);
        assert_eq!(a.cache.total_hits(), b.cache.total_hits());
        assert_eq!(a.cache.evicted_chunks, b.cache.evicted_chunks);
        assert_eq!(a.cache.rejected_inserts, b.cache.rejected_inserts);
        assert_eq!(a.prefetch_submitted, b.prefetch_submitted);
        assert_eq!(a.io.demand.submitted, b.io.demand.submitted);
    }

    #[test]
    fn io_lanes_report_lane_traffic() {
        let pcr = run_system("pcr", 0.8);
        // the prefetcher's counters and the lane counters must agree
        assert!(pcr.io.prefetch.submitted > 0, "no prefetch lane traffic");
        assert_eq!(pcr.io.prefetch.submitted, pcr.prefetch_submitted);
        assert_eq!(pcr.io.prefetch.completed, pcr.prefetch_completed);
        assert_eq!(pcr.io.prefetch.cancelled, pcr.prefetch_cancelled);
        // the report carries the same snapshot the outcome does
        assert_eq!(
            pcr.report.io.prefetch.submitted,
            pcr.io.prefetch.submitted
        );
        assert!(pcr.report.pretty().contains("upgraded"));
        // non-prefetching baselines move demand bytes only
        let scc = run_system("sccache", 0.8);
        assert_eq!(scc.io.prefetch.submitted, 0);
        assert!(scc.io.demand.submitted > 0, "sccache serves SSD demand reads");
        assert_eq!(scc.io.upgraded, 0);
    }

    #[test]
    fn chaos_faults_never_lose_requests_and_counters_reconcile() {
        // The headline robustness invariant: under ANY seeded fault
        // plan, every request completes and emits the same token
        // stream as the fault-free run — faults may only cost latency
        // and hit ratio — and the degradation counters account for
        // every injection the session made.
        use crate::util::proptest::{check, forall};
        use crate::util::rng::splitmix64;
        let base = test_cfg("pcr", 0.8);
        let wl = Workload::build(&base);
        let spec = SystemSpec::named("pcr", base.prefetch_window).unwrap();
        let clean = run(&base, &spec, &wl);
        let mut injected_total = 0u64;
        forall(
            0xFA117,
            5,
            |rng| rng.below(1 << 32),
            |&s| {
                let mut st = s;
                let mut cfg = test_cfg("pcr", 0.8);
                cfg.fault_seed = splitmix64(&mut st);
                cfg.fault_transient = (splitmix64(&mut st) % 16) as f64 / 100.0;
                cfg.fault_transient_attempts = 1 + (splitmix64(&mut st) % 3) as u32;
                cfg.fault_loss = (splitmix64(&mut st) % 8) as f64 / 100.0;
                cfg.fault_corrupt = (splitmix64(&mut st) % 8) as f64 / 100.0;
                cfg.fault_spike = (splitmix64(&mut st) % 10) as f64 / 100.0;
                let a = run(&cfg, &spec, &wl);
                let d = a.report.degrade;
                let i = a.injected;
                injected_total += i.lost + i.corrupted + i.retries + i.spikes;
                check(
                    a.report.finished == clean.report.finished,
                    format!("lost requests: {} != {}", a.report.finished, clean.report.finished),
                )?;
                check(
                    a.report.itl.n == clean.report.itl.n,
                    "token stream changed under faults",
                )?;
                check(
                    d.degraded_loads == i.degrading(),
                    format!("degraded {} != injected {}", d.degraded_loads, i.degrading()),
                )?;
                check(
                    d.quarantined_chunks == d.degraded_loads,
                    "every degrading fault quarantines exactly one chunk",
                )?;
                check(d.retries == i.retries, "retry accounting diverged")?;
                check(
                    d.failovers == 0 && d.store_errors == 0,
                    "virtual single-engine runs have no failovers/store errors",
                )?;
                // the faulted run must replay bit-for-bit under the
                // same plan (the decisions are pure functions of it)
                let b = run(&cfg, &spec, &wl);
                check(a.report.ttft.mean == b.report.ttft.mean, "ttft replay diverged")?;
                check(b.injected == i, "injection replay diverged")?;
                check(b.report.degrade == d, "degrade replay diverged")?;
                Ok(())
            },
        );
        assert!(injected_total > 0, "chaos sweep never injected anything");
    }

    #[test]
    fn total_ssd_loss_degrades_but_every_request_finishes() {
        let spec = SystemSpec::named("pcr", 4).unwrap();
        let base = test_cfg("pcr", 0.8);
        let wl = Workload::build(&base);
        let clean = run(&base, &spec, &wl);
        let mut cfg = test_cfg("pcr", 0.8);
        cfg.fault_loss = 1.0;
        let out = run(&cfg, &spec, &wl);
        assert_eq!(out.report.finished, 120, "loss must never fail a request");
        assert!(out.injected.lost > 0, "no loss injected");
        assert_eq!(out.report.degrade.degraded_loads, out.injected.degrading());
        assert_eq!(
            out.report.degrade.quarantined_chunks,
            out.report.degrade.degraded_loads
        );
        // SSD reuse-through-load is gone; GPU/DRAM reuse survives
        assert!(out.report.mean_reuse_ratio < clean.report.mean_reuse_ratio);
        assert!(out.report.mean_reuse_ratio > 0.0);
        assert!(out.report.pretty().contains("degrade loads="));
    }

    #[test]
    fn latency_spikes_slow_but_never_degrade() {
        let spec = SystemSpec::named("pcr", 4).unwrap();
        let base = test_cfg("pcr", 0.8);
        let wl = Workload::build(&base);
        let clean = run(&base, &spec, &wl);
        let mut cfg = test_cfg("pcr", 0.8);
        cfg.fault_spike = 1.0;
        cfg.fault_spike_seconds = 0.2;
        let out = run(&cfg, &spec, &wl);
        assert_eq!(out.report.finished, 120);
        assert!(out.injected.spikes > 0, "no spikes served");
        assert_eq!(out.injected.degrading(), 0);
        assert!(!out.report.degrade.any(), "spikes are latency-only");
        assert!(out.report.ttft.mean >= clean.report.ttft.mean);
    }

    #[test]
    fn e2el_exceeds_ttft() {
        let out = run_system("pcr", 0.5);
        assert!(out.report.e2el.mean > out.report.ttft.mean);
        assert!(out.report.itl.n > 0);
    }

    #[test]
    fn breakdown_sums_are_sane() {
        let out = run_system("pcr", 0.8);
        assert!(out.breakdown.compute > 0.0);
        assert!(out.breakdown.pipeline >= out.breakdown.compute * 0.99);
        assert!(out.breakdown.ssd_wait >= 0.0);
    }

    #[test]
    fn trace_disabled_by_default_and_records_nothing() {
        let out = run_system("pcr", 0.8);
        assert!(out.trace.is_empty());
        assert!(out.timeline.is_empty());
        assert!(out.flight.is_empty());
        assert_eq!(out.trace_dropped, 0);
    }

    #[test]
    fn null_sink_is_a_strict_noop() {
        // satellite invariant: with tracing (and the timeline) enabled,
        // the serving outcome is bit-identical to the disabled run —
        // obs must observe, never perturb
        let cfg = test_cfg("pcr", 0.8);
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        let off = run(&cfg, &spec, &wl);
        let mut traced = test_cfg("pcr", 0.8);
        traced.obs_trace = true;
        traced.obs_timeline = true;
        let on = run(&traced, &spec, &wl);
        assert!(!on.trace.is_empty(), "tracing on must record events");
        assert!(!on.timeline.is_empty(), "timeline on must sample");
        assert_eq!(off.report.ttft.mean, on.report.ttft.mean);
        assert_eq!(off.report.e2el.p99, on.report.e2el.p99);
        assert_eq!(off.report.itl.n, on.report.itl.n);
        assert_eq!(off.virtual_duration, on.virtual_duration);
        assert_eq!(off.cache.total_hits(), on.cache.total_hits());
        assert_eq!(off.cache.evicted_chunks, on.cache.evicted_chunks);
        assert_eq!(off.io.demand.submitted, on.io.demand.submitted);
        assert_eq!(off.prefetch_submitted, on.prefetch_submitted);
    }

    #[test]
    fn traces_replay_byte_identically() {
        use crate::obs::chrome_trace;
        let mut cfg = test_cfg("pcr", 0.8);
        cfg.obs_trace = true;
        cfg.obs_timeline = true;
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        let a = run(&cfg, &spec, &wl);
        let b = run(&cfg, &spec, &wl);
        assert!(!a.trace.is_empty());
        assert_eq!(a.trace, b.trace, "event streams diverged at a fixed seed");
        assert_eq!(a.timeline, b.timeline);
        let ja = chrome_trace(&[(0, &a.trace)]).dump();
        let jb = chrome_trace(&[(0, &b.trace)]).dump();
        assert_eq!(ja, jb, "chrome trace JSON must be byte-identical");
    }

    #[test]
    fn trace_covers_stage_cache_and_io_layers() {
        let mut cfg = test_cfg("pcr", 0.8);
        cfg.obs_trace = true;
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        let out = run(&cfg, &spec, &wl);
        let cats: std::collections::BTreeSet<&str> =
            out.trace.iter().map(|e| e.kind.category()).collect();
        for cat in ["stage", "cache", "io"] {
            assert!(cats.contains(cat), "no {cat} events in the trace");
        }
        let kinds: std::collections::BTreeSet<&str> =
            out.trace.iter().map(|e| e.kind.name()).collect();
        for kind in ["retrieval", "queue", "prefill", "kv_load", "cache_insert", "io_submit"] {
            assert!(kinds.contains(kind), "no {kind} events in the trace");
        }
    }

    #[test]
    fn flight_recorder_snapshots_on_degrade() {
        let mut cfg = test_cfg("pcr", 0.8);
        cfg.obs_trace = true;
        cfg.fault_loss = 1.0;
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        let out = run(&cfg, &spec, &wl);
        assert!(out.report.degrade.degraded_loads > 0, "loss plan must degrade");
        assert!(!out.flight.is_empty(), "degrade must trigger a flight snapshot");
        assert!(out.flight.iter().all(|s| s.reason == "degrade"));
        assert!(out.flight.iter().any(|s| !s.events.is_empty()));
    }

    #[test]
    fn timeline_samples_are_monotonic_and_bounded() {
        let mut cfg = test_cfg("pcr", 0.8);
        cfg.obs_timeline = true;
        cfg.obs_timeline_interval = 0.25;
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
        let out = run(&cfg, &spec, &wl);
        assert!(out.timeline.len() > 1, "expected multiple samples");
        for w in out.timeline.windows(2) {
            assert!(w[1].t > w[0].t, "sample times must strictly increase");
        }
        for s in &out.timeline {
            assert!(s.gpu_bytes <= cfg.gpu_bytes);
            assert!(s.dram_bytes <= cfg.dram_bytes);
            assert!(s.ssd_bytes <= cfg.ssd_bytes);
            assert!((0.0..=1.0).contains(&s.hit_ratio_window));
        }
    }

    #[test]
    fn breakdown_rows_reconcile_with_ttft() {
        // acceptance invariant: the attributed stages sum to the
        // recorded TTFT within 1e-9, over random rates and fault mixes
        use crate::util::proptest::{check, forall};
        use crate::util::rng::splitmix64;
        let spec = SystemSpec::named("pcr", 4).unwrap();
        forall(
            0x0B5EC0DE,
            4,
            |rng| rng.below(1 << 32),
            |&s| {
                let mut st = s;
                let rate = 0.4 + (splitmix64(&mut st) % 16) as f64 / 10.0;
                let mut cfg = test_cfg("pcr", rate);
                cfg.fault_seed = splitmix64(&mut st);
                cfg.fault_loss = (splitmix64(&mut st) % 6) as f64 / 100.0;
                cfg.fault_transient = (splitmix64(&mut st) % 10) as f64 / 100.0;
                cfg.fault_spike = (splitmix64(&mut st) % 10) as f64 / 100.0;
                let wl = Workload::build(&cfg);
                let out = run(&cfg, &spec, &wl);
                check(
                    out.attribution.rows.len() == out.report.finished,
                    "single-engine runs record one row per finished request",
                )?;
                let residual = out.attribution.max_residual();
                check(residual < 1e-9, format!("stage sum residual {residual}"))?;
                check(out.report.ttft_breakdown.any(), "summary missing from report")?;
                check(
                    (out.report.ttft_breakdown.ttft - out.report.ttft.mean).abs() < 1e-9,
                    "breakdown mean TTFT diverged from the recorded metric",
                )?;
                check(
                    out.report.pretty().contains("ttft ="),
                    "pretty report lost the breakdown block",
                )?;
                Ok(())
            },
        );
    }
}
