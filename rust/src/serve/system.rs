//! System variants (paper §6.1 Baselines + §6.3 breakdown arms).
//!
//! Every variant runs the same backbone (scheduler, chunked prefix
//! cache, cost models); they differ only in which tiers exist, which
//! transfers overlap, whether the queue drives prefetch, and the
//! eviction policy — exactly how the paper frames its baselines:
//!
//! | variant  | DRAM | SSD | overlap  | prefetch     | policy        |
//! |----------|------|-----|----------|--------------|---------------|
//! | vllm     |  –   |  –  | –        | none         | LRU (GPU)     |
//! | ccache   |  ✓   |  –  | sync     | none         | LRU           |
//! | sccache  |  ✓   |  ✓  | sync     | none         | LRU           |
//! | lmcache  |  ✓   |  ✓  | only-up  | queue (w=1)  | LRU           |
//! | pcr      |  ✓   |  ✓  | up-down  | queue (w=W)  | look-ahead LRU|
//!
//! Policy and strategy are registry *names* (see `cache::policy` and
//! `cache::prefetch`), so any registered combination — e.g. `slru` ×
//! `depth-bounded:4` — is one [`SystemSpec`] field (or one config/CLI
//! knob via [`SystemSpec::from_config`]) away.
//!
//! Table 1's arms: `pcr_base` (tiers only, sync, no prefetch),
//! `pcr_overlap` (+layer-wise overlap), `pcr` (+queue prefetch).

use crate::config::ExperimentConfig;
use crate::sim::pipeline::OverlapMode;
use anyhow::{anyhow, Result};

/// Behaviour switches of one serving system.
#[derive(Clone, Debug)]
pub struct SystemSpec {
    pub name: &'static str,
    pub dram_tier: bool,
    pub ssd_tier: bool,
    pub overlap: OverlapMode,
    /// Queue-based SSD→DRAM prefetch look-ahead window (0 = disabled).
    pub prefetch_window: usize,
    /// Look-ahead LRU protection from the waiting queue.
    pub lookahead_lru: bool,
    /// Eviction policy registry name (`cache::policy::registry`).
    pub policy: String,
    /// Prefetch strategy registry name (`cache::prefetch::registry`).
    pub prefetch_strategy: String,
    /// Batched chunk copies (`cudaMemcpyBatchAsync`) vs block-by-block.
    pub batch_async: bool,
    /// Dual-lane async SSD I/O (`io::VirtualLanes`): demand reads
    /// preempt queued prefetch work instead of sharing one FIFO with
    /// it. Systems without it serve demand reads behind whatever the
    /// shared channel is already doing — the synchronous-loading cost
    /// the paper's asynchronous design removes.
    pub async_io: bool,
}

impl SystemSpec {
    /// Registered system-variant names, in paper order.
    pub const NAMES: [&'static str; 5] = ["vllm", "ccache", "sccache", "lmcache", "pcr"];

    /// `", "`-joined [`NAMES`](Self::NAMES) for error messages.
    pub fn names_joined() -> String {
        Self::NAMES.join(", ")
    }

    /// The paper's five evaluated systems.
    pub fn named(name: &str, prefetch_window: usize) -> Option<SystemSpec> {
        let spec = match name {
            "vllm" => SystemSpec {
                name: "vllm",
                dram_tier: false,
                ssd_tier: false,
                overlap: OverlapMode::Sync,
                prefetch_window: 0,
                lookahead_lru: false,
                policy: "lru".into(),
                prefetch_strategy: "none".into(),
                batch_async: false,
                async_io: false,
            },
            "ccache" => SystemSpec {
                name: "ccache",
                dram_tier: true,
                ssd_tier: false,
                overlap: OverlapMode::Sync,
                prefetch_window: 0,
                lookahead_lru: false,
                policy: "lru".into(),
                prefetch_strategy: "none".into(),
                batch_async: false,
                async_io: false,
            },
            "sccache" => SystemSpec {
                name: "sccache",
                dram_tier: true,
                ssd_tier: true,
                overlap: OverlapMode::Sync,
                prefetch_window: 0,
                lookahead_lru: false,
                policy: "lru".into(),
                prefetch_strategy: "none".into(),
                batch_async: false,
                async_io: false,
            },
            "lmcache" => SystemSpec {
                name: "lmcache",
                dram_tier: true,
                ssd_tier: true,
                overlap: OverlapMode::OnlyUp,
                prefetch_window: 1,
                lookahead_lru: false,
                policy: "lru".into(),
                prefetch_strategy: "queue-window".into(),
                batch_async: true,
                async_io: true,
            },
            "pcr" => SystemSpec {
                name: "pcr",
                dram_tier: true,
                ssd_tier: true,
                overlap: OverlapMode::UpDown,
                prefetch_window,
                lookahead_lru: true,
                policy: "lookahead-lru".into(),
                prefetch_strategy: "queue-window".into(),
                batch_async: true,
                async_io: true,
            },
            _ => return None,
        };
        Some(spec)
    }

    /// Apply experiment-config overrides: an empty name keeps the
    /// system's default, so `cache.policy = "slru"` in a TOML file (or
    /// `--policy slru` on the CLI) swaps eviction without touching the
    /// rest of the variant. A policy override whose name starts with
    /// `lookahead` also enables the queue-driven boost pass it needs.
    pub fn with_overrides(mut self, policy: &str, prefetch_strategy: &str) -> SystemSpec {
        if !policy.is_empty() {
            self.policy = policy.to_ascii_lowercase();
            self.lookahead_lru = self.policy.starts_with("lookahead");
        }
        if !prefetch_strategy.is_empty() {
            self.prefetch_strategy = prefetch_strategy.to_ascii_lowercase();
        }
        self
    }

    /// [`named`](Self::named) as a proper error: unknown names list
    /// the registered systems, `Config::validate` style, instead of
    /// leaving every caller to panic on `None`.
    pub fn try_named(name: &str, prefetch_window: usize) -> Result<SystemSpec> {
        Self::named(name, prefetch_window).ok_or_else(|| {
            anyhow!(
                "unknown system '{}' (registered: {})",
                name,
                Self::names_joined()
            )
        })
    }

    /// The spec for `cfg.system` with `cfg`'s policy / prefetch
    /// strategy / window applied — the one-knob path from a validated
    /// config to any policy×strategy combination. Errors (rather than
    /// panicking downstream) on an unregistered system name.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<SystemSpec> {
        Ok(Self::try_named(&cfg.system, cfg.prefetch_window)?
            .with_overrides(&cfg.policy, &cfg.prefetch_strategy))
    }

    /// Table 1 ablation arms (cumulative).
    pub fn pcr_base() -> SystemSpec {
        SystemSpec {
            name: "pcr_base",
            overlap: OverlapMode::Sync,
            prefetch_window: 0,
            ..Self::named("pcr", 4).unwrap()
        }
    }

    pub fn pcr_overlap() -> SystemSpec {
        SystemSpec {
            name: "pcr_overlap",
            prefetch_window: 0,
            ..Self::named("pcr", 4).unwrap()
        }
    }

    /// Fig 18 arm with a specific overlap mode.
    pub fn pcr_with_overlap(mode: OverlapMode) -> SystemSpec {
        SystemSpec {
            name: match mode {
                OverlapMode::Sync => "pcr_sync",
                OverlapMode::OnlyUp => "pcr_only_up",
                OverlapMode::OnlyDown => "pcr_only_down",
                OverlapMode::UpDown => "pcr_up_down",
            },
            overlap: mode,
            ..Self::named("pcr", 4).unwrap()
        }
    }

    pub fn all_baselines(prefetch_window: usize) -> Vec<SystemSpec> {
        Self::NAMES
            .iter()
            .map(|n| Self::named(n, prefetch_window).unwrap())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_variants_match_paper_table() {
        let v = SystemSpec::named("vllm", 4).unwrap();
        assert!(!v.dram_tier && !v.ssd_tier);
        assert_eq!(v.prefetch_strategy, "none");
        let c = SystemSpec::named("ccache", 4).unwrap();
        assert!(c.dram_tier && !c.ssd_tier);
        assert_eq!(c.overlap, OverlapMode::Sync);
        let s = SystemSpec::named("sccache", 4).unwrap();
        assert!(s.dram_tier && s.ssd_tier);
        assert!(!s.async_io, "sccache loads demand reads synchronously");
        let p = SystemSpec::named("pcr", 6).unwrap();
        assert_eq!(p.prefetch_window, 6);
        assert!(p.lookahead_lru);
        assert!(p.async_io && SystemSpec::named("lmcache", 4).unwrap().async_io);
        assert_eq!(p.policy, "lookahead-lru");
        assert_eq!(p.prefetch_strategy, "queue-window");
        assert!(SystemSpec::named("orca", 4).is_none());
    }

    #[test]
    fn ablation_arms_are_cumulative() {
        let base = SystemSpec::pcr_base();
        let ovl = SystemSpec::pcr_overlap();
        let full = SystemSpec::named("pcr", 4).unwrap();
        assert_eq!(base.overlap, OverlapMode::Sync);
        assert_eq!(base.prefetch_window, 0);
        assert_eq!(ovl.overlap, OverlapMode::UpDown);
        assert_eq!(ovl.prefetch_window, 0);
        assert_eq!(full.prefetch_window, 4);
        // all three share tiers + policy
        assert!(base.dram_tier && base.ssd_tier && base.lookahead_lru);
    }

    #[test]
    fn all_baselines_count() {
        assert_eq!(SystemSpec::all_baselines(4).len(), 5);
    }

    #[test]
    fn try_named_errors_list_registered_names() {
        assert!(SystemSpec::try_named("pcr", 4).is_ok());
        let err = SystemSpec::try_named("orca", 4).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("orca"), "{msg}");
        for name in SystemSpec::NAMES {
            assert!(msg.contains(name), "missing {name} in: {msg}");
        }
        let mut cfg = ExperimentConfig::default();
        cfg.system = "orca".into();
        assert!(SystemSpec::from_config(&cfg).is_err());
    }

    #[test]
    fn config_overrides_swap_policy_and_strategy() {
        let mut cfg = ExperimentConfig::default();
        cfg.system = "pcr".into();
        cfg.policy = "SLRU".into();
        cfg.prefetch_strategy = "depth-bounded:4".into();
        let spec = SystemSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.policy, "slru");
        assert!(!spec.lookahead_lru, "non-lookahead policy disables boosting");
        assert_eq!(spec.prefetch_strategy, "depth-bounded:4");

        // lookahead-family override re-enables the boost pass, even on
        // a baseline that never boosts by default
        let mut cfg = ExperimentConfig::default();
        cfg.system = "sccache".into();
        cfg.policy = "lookahead-slru".into();
        let spec = SystemSpec::from_config(&cfg).unwrap();
        assert!(spec.lookahead_lru);

        // empty overrides keep system defaults
        let cfg = ExperimentConfig::default();
        let spec = SystemSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.policy, "lookahead-lru");
        assert_eq!(spec.prefetch_strategy, "queue-window");
    }
}
