//! The scheduler's waiting queue with the look-ahead window view that
//! drives both look-ahead LRU protection and queue-based prefetching
//! (paper §4.2/§4.4, Fig 12).

use crate::serve::request::Request;
use std::collections::VecDeque;

/// FCFS waiting queue.
#[derive(Debug, Default)]
pub struct WaitingQueue {
    items: VecDeque<Request>,
}

impl WaitingQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: Request) {
        self.items.push_back(r);
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.items.pop_front()
    }

    pub fn front(&self) -> Option<&Request> {
        self.items.front()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The first `window` queued requests (the prefetcher's look-ahead
    /// window). Algorithm 1 iterates this in *reverse* so that the
    /// request served soonest submits its SSD loads last and therefore
    /// ends up at the *head* of the FIFO SSD queue... no — reverse
    /// iteration makes the soonest request's loads the *most recent*
    /// `BumpPriority` (strongest LRU protection). Transfer ordering is
    /// handled by the channel FIFO; see `engine`.
    pub fn window(&self, window: usize) -> impl DoubleEndedIterator<Item = &Request> {
        self.items.iter().take(window)
    }

    /// Iterate everything (metrics/debug).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.items.iter()
    }

    /// Take every queued request out, front-to-back (replica failover:
    /// the queue's work is evacuated for re-routing).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.items.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::ChunkedSeq;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        let tokens: Vec<u32> = (0..64).collect();
        let chain = ChunkedSeq::new(&tokens, 32);
        Request::new(id, id as u32, tokens.into(), Arc::new(chain), 4, 0.0, 0.0)
    }

    #[test]
    fn fcfs_order() {
        let mut q = WaitingQueue::new();
        for i in 0..5 {
            q.push(req(i));
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.front().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn window_is_prefix_and_reversible() {
        let mut q = WaitingQueue::new();
        for i in 0..10 {
            q.push(req(i));
        }
        let ids: Vec<u64> = q.window(4).map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        let rev: Vec<u64> = q.window(4).rev().map(|r| r.id).collect();
        assert_eq!(rev, vec![3, 2, 1, 0]);
        // window larger than queue is fine
        assert_eq!(q.window(99).count(), 10);
    }
}
