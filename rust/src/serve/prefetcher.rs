//! Queue-based SSD→DRAM prefetcher (paper §4.4, Fig 12): the *mover*
//! half of prefetching.
//!
//! Target selection is the pluggable half — a
//! [`PrefetchStrategy`](crate::cache::prefetch::PrefetchStrategy)
//! inspects the waiting queue's look-ahead window and hands this mover
//! the SSD-resident chunks worth promoting; the mover submits
//! asynchronous loads on the SSD read channel, de-duplicates in-flight
//! work, and drains completions into DRAM. Demand loads for the request
//! being scheduled share the same FIFO channel, so prefetch backlog and
//! demand traffic contend — exactly the trade-off the paper's bounded
//! window manages.

use crate::cache::engine::CacheEngine;
use crate::cache::prefix_tree::NodeId;
use crate::cache::tier::Tier;
use crate::hw::transfer::Channel;
use std::collections::BTreeMap;

/// Virtual-time prefetcher state.
#[derive(Debug, Default)]
pub struct SimPrefetcher {
    /// node -> absolute completion time of its in-flight SSD read.
    inflight: BTreeMap<NodeId, f64>,
    pub submitted: u64,
    pub completed: u64,
    /// Prefetched chunks that could not be promoted (DRAM full).
    pub dropped: u64,
}

impl SimPrefetcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit loads for strategy-selected `targets`, skipping chunks
    /// already in flight and (defensively) targets that are no longer
    /// SSD-only — a strategy may hand back stale or duplicate entries.
    /// Returns the number of new submissions.
    pub fn submit_targets(
        &mut self,
        cache: &CacheEngine,
        ssd_read: &mut Channel,
        now: f64,
        targets: &[NodeId],
    ) -> usize {
        let mut n = 0;
        for &id in targets {
            if self.inflight.contains_key(&id) {
                continue;
            }
            let t = cache.tree.node(id).tiers;
            if !t.contains(Tier::Ssd) || t.contains(Tier::Dram) || t.contains(Tier::Gpu) {
                continue;
            }
            let bytes = cache.tree.node(id).bytes;
            let (_, finish) = ssd_read.enqueue(now, bytes);
            self.inflight.insert(id, finish);
            self.submitted += 1;
            n += 1;
        }
        n
    }

    /// Submit prefetch loads for every SSD-resident chunk of `chain`
    /// (Algorithm 1's `SubmitSSDToCPULoad`) — the single-chain
    /// convenience the `queue-window` strategy generalises.
    pub fn submit_chain(
        &mut self,
        cache: &CacheEngine,
        ssd_read: &mut Channel,
        now: f64,
        chain: &[crate::cache::chunk::ChunkKey],
    ) -> usize {
        let targets = cache.prefetch_targets(chain);
        self.submit_targets(cache, ssd_read, now, &targets)
    }

    /// If `id` is being prefetched, when will it land in DRAM?
    pub fn ready_at(&self, id: NodeId) -> Option<f64> {
        self.inflight.get(&id).copied()
    }

    /// Promote every load that has completed by `now` into DRAM
    /// (Algorithm 1's `DrainCompletedSSDLoads`). Chunks that no longer
    /// fit (DRAM pressure) stay on SSD and count as `dropped`.
    pub fn drain(&mut self, cache: &mut CacheEngine, now: f64) {
        let done: Vec<NodeId> = self
            .inflight
            .iter()
            .filter(|(_, t)| **t <= now)
            .map(|(id, _)| *id)
            .collect();
        for id in done {
            self.inflight.remove(&id);
            self.completed += 1;
            // The chunk may have been evicted from SSD meanwhile; only
            // promote if it is still resident somewhere.
            if cache.tree.node(id).tiers.contains(Tier::Ssd)
                && !cache.tree.node(id).tiers.contains(Tier::Dram)
            {
                if !cache.promote(id, Tier::Dram) {
                    self.dropped += 1;
                }
            }
        }
    }

    pub fn inflight_count(&self) -> usize {
        self.inflight.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};
    use crate::cache::engine::{CacheConfig, CacheEngine};

    const CB: u64 = 1_000_000; // 1 MB chunks

    fn setup() -> (CacheEngine, Channel) {
        let cache = CacheEngine::new(CacheConfig {
            chunk_tokens: 256,
            gpu_capacity: 100 * CB,
            dram_capacity: 3 * CB,
            ssd_capacity: 100 * CB,
            policy: "lookahead-lru".into(),
        });
        (cache, Channel::new("ssd-read", 0.001, 0.0)) // 1 MB/s => 1s per chunk
    }

    fn chain(cache: &mut CacheEngine, tag: u32, n: usize) -> Vec<ChunkKey> {
        let mut keys = Vec::new();
        let mut parent_key = ChunkKey::ROOT;
        let mut parent = None;
        for i in 0..n {
            let k = chain_hash(parent_key, &[tag, i as u32]);
            parent = cache.insert(parent, k, CB, Tier::Ssd);
            keys.push(k);
            parent_key = k;
        }
        keys
    }

    #[test]
    fn submits_and_drains_in_order() {
        let (mut cache, mut ch) = setup();
        let keys = chain(&mut cache, 1, 2);
        let mut pf = SimPrefetcher::new();
        let n = pf.submit_chain(&cache, &mut ch, 0.0, &keys);
        assert_eq!(n, 2);
        assert_eq!(pf.inflight_count(), 2);
        // nothing ready at t=0.5
        pf.drain(&mut cache, 0.5);
        assert_eq!(pf.completed, 0);
        // first chunk lands at 1.0, second at 2.0 (FIFO channel)
        pf.drain(&mut cache, 1.0);
        assert_eq!(pf.completed, 1);
        let id0 = cache.tree.get(keys[0]).unwrap();
        assert!(cache.tree.node(id0).tiers.contains(Tier::Dram));
        pf.drain(&mut cache, 2.0);
        assert_eq!(pf.completed, 2);
        cache.check_accounting().unwrap();
    }

    #[test]
    fn no_duplicate_submission() {
        let (mut cache, mut ch) = setup();
        let keys = chain(&mut cache, 2, 2);
        let mut pf = SimPrefetcher::new();
        assert_eq!(pf.submit_chain(&cache, &mut ch, 0.0, &keys), 2);
        assert_eq!(pf.submit_chain(&cache, &mut ch, 0.1, &keys), 0);
        assert_eq!(pf.submitted, 2);
    }

    #[test]
    fn ready_at_reports_channel_finish() {
        let (mut cache, mut ch) = setup();
        let keys = chain(&mut cache, 3, 1);
        let mut pf = SimPrefetcher::new();
        pf.submit_chain(&cache, &mut ch, 0.0, &keys);
        let id = cache.tree.get(keys[0]).unwrap();
        assert!((pf.ready_at(id).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dram_pressure_counts_drops() {
        let (mut cache, mut ch) = setup();
        // DRAM fits 3 chunks; chain of 5 on SSD
        let keys = chain(&mut cache, 4, 5);
        let mut pf = SimPrefetcher::new();
        pf.submit_chain(&cache, &mut ch, 0.0, &keys);
        pf.drain(&mut cache, 100.0);
        assert_eq!(pf.completed, 5);
        // DRAM holds at most 3 chunks; later promotions may evict
        // earlier ones (legal — they keep their SSD copies), so the
        // binding constraints are capacity and accounting, not which
        // exact chunks survived.
        let in_dram = keys
            .iter()
            .filter(|k| {
                cache
                    .tree
                    .get(**k)
                    .map(|id| cache.tree.node(id).tiers.contains(Tier::Dram))
                    .unwrap_or(false)
            })
            .count();
        assert!(in_dram <= 3, "in_dram={in_dram}");
        assert!(in_dram >= 1);
        cache.check_accounting().unwrap();
    }

    #[test]
    fn stale_and_duplicate_targets_are_skipped() {
        let (mut cache, mut ch) = setup();
        let keys = chain(&mut cache, 6, 2);
        let ids: Vec<NodeId> = keys
            .iter()
            .map(|k| cache.tree.get(*k).unwrap())
            .collect();
        cache.promote(ids[0], Tier::Dram); // no longer SSD-only
        let mut pf = SimPrefetcher::new();
        let n = pf.submit_targets(&cache, &mut ch, 0.0, &[ids[0], ids[1], ids[1]]);
        assert_eq!(n, 1, "stale + in-call duplicate must be skipped");
        assert_eq!(pf.submitted, 1);
    }

    #[test]
    fn dram_resident_chunks_not_prefetched() {
        let (mut cache, mut ch) = setup();
        let keys = chain(&mut cache, 5, 2);
        let id0 = cache.tree.get(keys[0]).unwrap();
        cache.promote(id0, Tier::Dram);
        let mut pf = SimPrefetcher::new();
        assert_eq!(pf.submit_chain(&cache, &mut ch, 0.0, &keys), 1);
    }
}
