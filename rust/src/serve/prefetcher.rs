//! Queue-based SSD→DRAM prefetcher (paper §4.4, Fig 12): the *mover*
//! half of prefetching.
//!
//! Target selection is the pluggable half — a
//! [`PrefetchStrategy`](crate::cache::prefetch::PrefetchStrategy)
//! inspects the waiting queue's look-ahead window and hands this mover
//! the SSD-resident chunks worth promoting; the mover submits loads on
//! the **prefetch lane** of the dual-lane transfer model
//! ([`VirtualLanes`](crate::io::VirtualLanes) — the virtual-time twin
//! of the real [`io::TransferEngine`](crate::io::TransferEngine)),
//! de-duplicates in-flight work, honours the bounded-queue depth
//! (backpressure), cancels loads whose target became stale before the
//! read started, upgrades loads the demand path claims, and drains
//! completions into DRAM. Demand loads run on the demand lane, which
//! preempts queued prefetch work — exactly the trade-off the paper's
//! bounded window manages.

use crate::cache::engine::CacheEngine;
use crate::cache::prefix_tree::NodeId;
use crate::cache::tier::Tier;
use crate::io::{Lane, VirtualLanes};
use crate::obs::trace::{Kind, Phase, TraceEvent, Tracer, Track};
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug)]
struct Inflight {
    start: f64,
    finish: f64,
}

/// Virtual-time prefetcher state.
#[derive(Debug, Default)]
pub struct SimPrefetcher {
    /// node -> (start, finish) of its in-flight SSD read.
    inflight: BTreeMap<NodeId, Inflight>,
    pub submitted: u64,
    pub completed: u64,
    /// Prefetched chunks that could not be promoted (DRAM full).
    pub dropped: u64,
    /// Loads abandoned before their read started (stale target).
    pub cancelled: u64,
}

impl SimPrefetcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit prefetch-lane loads for strategy-selected `targets`,
    /// skipping chunks already in flight and (defensively) targets that
    /// are no longer SSD-only — a strategy may hand back stale or
    /// duplicate entries. At most `depth` loads may be in flight at
    /// once; targets beyond the bound are rejected (counted on the
    /// prefetch lane) rather than queued unboundedly. Returns the
    /// number of new submissions.
    pub fn submit_targets(
        &mut self,
        cache: &CacheEngine,
        lanes: &mut VirtualLanes,
        now: f64,
        targets: &[NodeId],
        depth: usize,
        tracer: &mut Tracer,
    ) -> usize {
        let mut n = 0;
        for &id in targets {
            if self.inflight.contains_key(&id) {
                continue;
            }
            let t = cache.tree.node(id).tiers;
            if !t.contains(Tier::Ssd) || t.contains(Tier::Dram) || t.contains(Tier::Gpu) {
                continue;
            }
            if self.inflight.len() >= depth.max(1) {
                lanes.stats.prefetch.rejected += 1;
                continue;
            }
            let bytes = cache.tree.node(id).bytes;
            let (start, finish) = lanes.enqueue(Lane::Prefetch, now, bytes);
            self.inflight.insert(id, Inflight { start, finish });
            self.submitted += 1;
            n += 1;
            tracer.emit(|| TraceEvent {
                t: now,
                track: Track::LanePrefetch,
                kind: Kind::IoSubmit,
                id: id.0 as u64,
                phase: Phase::Instant,
            });
            tracer.emit(|| TraceEvent {
                t: start,
                track: Track::LanePrefetch,
                kind: Kind::KvLoad,
                id: id.0 as u64,
                phase: Phase::Complete(finish - start),
            });
        }
        n
    }

    /// Submit prefetch loads for every SSD-resident chunk of `chain`
    /// (Algorithm 1's `SubmitSSDToCPULoad`) — the single-chain
    /// convenience the `queue-window` strategy generalises.
    pub fn submit_chain(
        &mut self,
        cache: &CacheEngine,
        lanes: &mut VirtualLanes,
        now: f64,
        chain: &[crate::cache::chunk::ChunkKey],
        depth: usize,
        tracer: &mut Tracer,
    ) -> usize {
        let targets = cache.prefetch_targets(chain);
        self.submit_targets(cache, lanes, now, &targets, depth, tracer)
    }

    /// If `id` is being prefetched, when will it land in DRAM?
    pub fn ready_at(&self, id: NodeId) -> Option<f64> {
        self.inflight.get(&id).map(|f| f.finish)
    }

    /// Demand-claim an in-flight prefetch of `id` (the engine's demand
    /// path found the chunk already on its way): the load is served
    /// once. If the read has not started yet it is re-issued at demand
    /// priority (the real engine moves the ticket between queues);
    /// if it is already on the device it completes on schedule.
    /// Returns the upgraded ready time, or `None` if `id` is not in
    /// flight.
    pub fn upgrade(
        &mut self,
        cache: &CacheEngine,
        lanes: &mut VirtualLanes,
        now: f64,
        id: NodeId,
        tracer: &mut Tracer,
    ) -> Option<f64> {
        let entry = self.inflight.get_mut(&id)?;
        lanes.stats.upgraded += 1;
        tracer.emit(|| TraceEvent {
            t: now,
            track: Track::LaneDemand,
            kind: Kind::IoUpgrade,
            id: id.0 as u64,
            phase: Phase::Instant,
        });
        if entry.start > now {
            let bytes = cache.tree.node(id).bytes;
            let (start, finish) = lanes.reserve(Lane::Demand, now, bytes);
            entry.start = start;
            entry.finish = finish;
            tracer.emit(|| TraceEvent {
                t: start,
                track: Track::LaneDemand,
                kind: Kind::KvLoad,
                id: id.0 as u64,
                phase: Phase::Complete(finish - start),
            });
        }
        Some(entry.finish)
    }

    /// Drop in-flight loads whose read has not started by `now` and
    /// whose target is no longer worth moving (evicted from SSD, or
    /// already DRAM/GPU-resident) — the virtual-time analogue of
    /// cancellation tokens: stale work is dropped before it hits disk.
    /// Returns the number of cancelled loads.
    pub fn cancel_stale(
        &mut self,
        cache: &CacheEngine,
        lanes: &mut VirtualLanes,
        now: f64,
        tracer: &mut Tracer,
    ) -> usize {
        let stale: Vec<NodeId> = self
            .inflight
            .iter()
            .filter(|(id, f)| {
                if f.start <= now {
                    return false; // already on the device: let it finish
                }
                let t = cache.tree.node(**id).tiers;
                !t.contains(Tier::Ssd) || t.contains(Tier::Dram) || t.contains(Tier::Gpu)
            })
            .map(|(id, _)| *id)
            .collect();
        for id in &stale {
            self.inflight.remove(id);
            self.cancelled += 1;
            lanes.stats.prefetch.cancelled += 1;
            tracer.emit(|| TraceEvent {
                t: now,
                track: Track::LanePrefetch,
                kind: Kind::IoCancel,
                id: id.0 as u64,
                phase: Phase::Instant,
            });
        }
        stale.len()
    }

    /// Promote every load that has completed by `now` into DRAM
    /// (Algorithm 1's `DrainCompletedSSDLoads`). Chunks that no longer
    /// fit (DRAM pressure) stay on SSD and count as `dropped`.
    pub fn drain(
        &mut self,
        cache: &mut CacheEngine,
        lanes: &mut VirtualLanes,
        now: f64,
        tracer: &mut Tracer,
    ) {
        let done: Vec<(NodeId, f64)> = self
            .inflight
            .iter()
            .filter(|(_, f)| f.finish <= now)
            .map(|(id, f)| (*id, f.finish))
            .collect();
        for (id, finish) in done {
            self.inflight.remove(&id);
            self.completed += 1;
            lanes.stats.prefetch.completed += 1;
            tracer.emit(|| TraceEvent {
                t: finish,
                track: Track::LanePrefetch,
                kind: Kind::IoComplete,
                id: id.0 as u64,
                phase: Phase::Instant,
            });
            // The chunk may have been evicted from SSD meanwhile; only
            // promote if it is still resident somewhere.
            if cache.tree.node(id).tiers.contains(Tier::Ssd)
                && !cache.tree.node(id).tiers.contains(Tier::Dram)
            {
                if !cache.promote(id, Tier::Dram) {
                    self.dropped += 1;
                }
            }
        }
    }

    pub fn inflight_count(&self) -> usize {
        self.inflight.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::{chain_hash, ChunkKey};
    use crate::cache::engine::{CacheConfig, CacheEngine};

    const CB: u64 = 1_000_000; // 1 MB chunks
    const DEEP: usize = usize::MAX; // unbounded depth for legacy cases

    fn setup() -> (CacheEngine, VirtualLanes, Tracer) {
        let cache = CacheEngine::new(CacheConfig {
            chunk_tokens: 256,
            gpu_capacity: 100 * CB,
            dram_capacity: 3 * CB,
            ssd_capacity: 100 * CB,
            policy: "lookahead-lru".into(),
        });
        // 1 MB/s => 1s per chunk
        (cache, VirtualLanes::new(0.001, 0.0), Tracer::off())
    }

    fn chain(cache: &mut CacheEngine, tag: u32, n: usize) -> Vec<ChunkKey> {
        let mut keys = Vec::new();
        let mut parent_key = ChunkKey::ROOT;
        let mut parent = None;
        for i in 0..n {
            let k = chain_hash(parent_key, &[tag, i as u32]);
            parent = cache.insert(parent, k, CB, Tier::Ssd);
            keys.push(k);
            parent_key = k;
        }
        keys
    }

    #[test]
    fn submits_and_drains_in_order() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 1, 2);
        let mut pf = SimPrefetcher::new();
        let n = pf.submit_chain(&cache, &mut lanes, 0.0, &keys, DEEP, &mut tr);
        assert_eq!(n, 2);
        assert_eq!(pf.inflight_count(), 2);
        // nothing ready at t=0.5
        pf.drain(&mut cache, &mut lanes, 0.5, &mut tr);
        assert_eq!(pf.completed, 0);
        // first chunk lands at 1.0, second at 2.0 (FIFO lane)
        pf.drain(&mut cache, &mut lanes, 1.0, &mut tr);
        assert_eq!(pf.completed, 1);
        let id0 = cache.tree.get(keys[0]).unwrap();
        assert!(cache.tree.node(id0).tiers.contains(Tier::Dram));
        pf.drain(&mut cache, &mut lanes, 2.0, &mut tr);
        assert_eq!(pf.completed, 2);
        assert_eq!(lanes.stats.prefetch.completed, 2);
        cache.check_accounting().unwrap();
    }

    #[test]
    fn no_duplicate_submission() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 2, 2);
        let mut pf = SimPrefetcher::new();
        assert_eq!(pf.submit_chain(&cache, &mut lanes, 0.0, &keys, DEEP, &mut tr), 2);
        assert_eq!(pf.submit_chain(&cache, &mut lanes, 0.1, &keys, DEEP, &mut tr), 0);
        assert_eq!(pf.submitted, 2);
        assert_eq!(lanes.stats.prefetch.submitted, 2);
    }

    #[test]
    fn ready_at_reports_lane_finish() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 3, 1);
        let mut pf = SimPrefetcher::new();
        pf.submit_chain(&cache, &mut lanes, 0.0, &keys, DEEP, &mut tr);
        let id = cache.tree.get(keys[0]).unwrap();
        assert!((pf.ready_at(id).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dram_pressure_counts_drops() {
        let (mut cache, mut lanes, mut tr) = setup();
        // DRAM fits 3 chunks; chain of 5 on SSD
        let keys = chain(&mut cache, 4, 5);
        let mut pf = SimPrefetcher::new();
        pf.submit_chain(&cache, &mut lanes, 0.0, &keys, DEEP, &mut tr);
        pf.drain(&mut cache, &mut lanes, 100.0, &mut tr);
        assert_eq!(pf.completed, 5);
        // DRAM holds at most 3 chunks; later promotions may evict
        // earlier ones (legal — they keep their SSD copies), so the
        // binding constraints are capacity and accounting, not which
        // exact chunks survived.
        let in_dram = keys
            .iter()
            .filter(|k| {
                cache
                    .tree
                    .get(**k)
                    .map(|id| cache.tree.node(id).tiers.contains(Tier::Dram))
                    .unwrap_or(false)
            })
            .count();
        assert!(in_dram <= 3, "in_dram={in_dram}");
        assert!(in_dram >= 1);
        cache.check_accounting().unwrap();
    }

    #[test]
    fn stale_and_duplicate_targets_are_skipped() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 6, 2);
        let ids: Vec<NodeId> = keys
            .iter()
            .map(|k| cache.tree.get(*k).unwrap())
            .collect();
        cache.promote(ids[0], Tier::Dram); // no longer SSD-only
        let mut pf = SimPrefetcher::new();
        let n =
            pf.submit_targets(&cache, &mut lanes, 0.0, &[ids[0], ids[1], ids[1]], DEEP, &mut tr);
        assert_eq!(n, 1, "stale + in-call duplicate must be skipped");
        assert_eq!(pf.submitted, 1);
    }

    #[test]
    fn dram_resident_chunks_not_prefetched() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 5, 2);
        let id0 = cache.tree.get(keys[0]).unwrap();
        cache.promote(id0, Tier::Dram);
        let mut pf = SimPrefetcher::new();
        assert_eq!(pf.submit_chain(&cache, &mut lanes, 0.0, &keys, DEEP, &mut tr), 1);
    }

    #[test]
    fn bounded_depth_applies_backpressure() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 7, 5);
        let mut pf = SimPrefetcher::new();
        let n = pf.submit_chain(&cache, &mut lanes, 0.0, &keys, 2, &mut tr);
        assert_eq!(n, 2, "depth 2 admits two loads");
        assert_eq!(lanes.stats.prefetch.rejected, 3);
        // drain frees slots: resubmission admits the rest
        pf.drain(&mut cache, &mut lanes, 10.0, &mut tr);
        let n2 = pf.submit_chain(&cache, &mut lanes, 10.0, &keys, 2, &mut tr);
        assert_eq!(n2, 2);
    }

    #[test]
    fn upgrade_claims_queued_load_at_demand_priority() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 8, 3);
        let ids: Vec<NodeId> = keys.iter().map(|k| cache.tree.get(*k).unwrap()).collect();
        let mut pf = SimPrefetcher::new();
        pf.submit_targets(&cache, &mut lanes, 0.0, &ids, DEEP, &mut tr);
        // third load queues behind two others: starts at 2.0
        assert!((pf.ready_at(ids[2]).unwrap() - 3.0).abs() < 1e-9);
        // a demand claim at t=0 re-issues it on the demand lane (1s)
        let t = pf.upgrade(&cache, &mut lanes, 0.0, ids[2], &mut tr).unwrap();
        assert!((t - 1.0).abs() < 1e-9, "upgraded ready {t}");
        assert_eq!(lanes.stats.upgraded, 1);
        // a load already on the device keeps its schedule
        let t0 = pf.upgrade(&cache, &mut lanes, 0.5, ids[0], &mut tr).unwrap();
        assert!((t0 - 1.0).abs() < 1e-9);
        // unknown node: no upgrade
        pf.drain(&mut cache, &mut lanes, 10.0, &mut tr);
        assert!(pf.upgrade(&cache, &mut lanes, 10.0, ids[0], &mut tr).is_none());
    }

    #[test]
    fn quarantined_targets_are_cancelled_not_promoted() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 10, 3);
        let ids: Vec<NodeId> = keys.iter().map(|k| cache.tree.get(*k).unwrap()).collect();
        let mut pf = SimPrefetcher::new();
        pf.submit_targets(&cache, &mut lanes, 0.0, &ids, DEEP, &mut tr);
        assert_eq!(pf.inflight_count(), 3);
        // the middle chunk's stored copy turned out unreadable: the
        // engine quarantines it and its resident subtree (ids[2] goes
        // too — unreachable behind the hole)
        cache.quarantine(ids[1]);
        // loads start at 0/1/2s; at t=0.5 the reads for ids[1..] have
        // not started — they cancel instead of promoting ghosts
        let n = pf.cancel_stale(&cache, &mut lanes, 0.5, &mut tr);
        assert_eq!(n, 2);
        assert_eq!(pf.inflight_count(), 1);
        // the started load for the still-resident ids[0] lands fine
        pf.drain(&mut cache, &mut lanes, 10.0, &mut tr);
        assert_eq!(pf.completed, 1);
        assert_eq!(pf.dropped, 0);
        cache.check_accounting().unwrap();
    }

    #[test]
    fn cancel_stale_drops_unstarted_loads_only() {
        let (mut cache, mut lanes, mut tr) = setup();
        let keys = chain(&mut cache, 9, 3);
        let ids: Vec<NodeId> = keys.iter().map(|k| cache.tree.get(*k).unwrap()).collect();
        let mut pf = SimPrefetcher::new();
        pf.submit_targets(&cache, &mut lanes, 0.0, &ids, DEEP, &mut tr);
        // loads start at 0.0 / 1.0 / 2.0; make all targets stale
        for &id in &ids {
            cache.promote(id, Tier::Dram);
        }
        // at t=0.5 only the 2nd and 3rd loads haven't started
        let n = pf.cancel_stale(&cache, &mut lanes, 0.5, &mut tr);
        assert_eq!(n, 2);
        assert_eq!(pf.cancelled, 2);
        assert_eq!(lanes.stats.prefetch.cancelled, 2);
        assert_eq!(pf.inflight_count(), 1, "started load keeps going");
        pf.drain(&mut cache, &mut lanes, 10.0, &mut tr);
        assert_eq!(pf.completed, 1);
        cache.check_accounting().unwrap();
    }

    #[test]
    fn enabled_tracer_sees_the_full_io_lifecycle() {
        let (mut cache, mut lanes, _) = setup();
        let mut tr = Tracer::ring(64);
        let keys = chain(&mut cache, 11, 3);
        let ids: Vec<NodeId> = keys.iter().map(|k| cache.tree.get(*k).unwrap()).collect();
        let mut pf = SimPrefetcher::new();
        pf.submit_targets(&cache, &mut lanes, 0.0, &ids, DEEP, &mut tr);
        pf.upgrade(&cache, &mut lanes, 0.0, ids[2], &mut tr);
        cache.promote(ids[1], Tier::Dram); // stale before its read starts
        pf.cancel_stale(&cache, &mut lanes, 0.5, &mut tr);
        pf.drain(&mut cache, &mut lanes, 10.0, &mut tr);
        let kinds: std::collections::BTreeSet<&str> =
            tr.take().iter().map(|e| e.kind.name()).collect();
        for want in ["io_submit", "io_complete", "io_cancel", "io_upgrade", "kv_load"] {
            assert!(kinds.contains(want), "missing {want} in {kinds:?}");
        }
    }
}
