//! Serving metrics: TTFT / E2EL / ITL / queueing collectors and the
//! per-run report every bench prints (the paper's Figs 14–16 rows).

use crate::io::IoStats;
use crate::serve::request::Request;
use crate::util::stats::{Samples, Summary};

/// All samples collected over one serving run.
#[derive(Clone, Debug, Default)]
pub struct MetricsCollector {
    pub ttft: Samples,
    pub e2el: Samples,
    pub itl: Samples,
    pub queue_time: Samples,
    pub compute_time: Samples,
    pub retrieval_time: Samples,
    /// Per-request reuse ratio (reused / total tokens).
    pub reuse_ratio: Samples,
    pub finished: usize,
    /// Transfer-lane counters (set by the engine before `report`).
    pub io: IoStats,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ingest a finished request.
    pub fn record(&mut self, r: &Request) {
        debug_assert!(r.finished_at.is_some());
        if let Some(x) = r.ttft() {
            self.ttft.push(x);
        }
        if let Some(x) = r.e2el() {
            self.e2el.push(x);
        }
        if let Some(x) = r.queue_time() {
            self.queue_time.push(x);
        }
        if let Some(x) = r.compute_time() {
            self.compute_time.push(x);
        }
        for &gap in &r.itl {
            self.itl.push(gap);
        }
        let total = r.total_tokens().max(1);
        self.reuse_ratio
            .push(r.reused_tokens as f64 / total as f64);
        self.finished += 1;
    }

    pub fn report(&mut self) -> Report {
        Report {
            finished: self.finished,
            ttft: self.ttft.summary(),
            e2el: self.e2el.summary(),
            itl: self.itl.summary(),
            queue_time: self.queue_time.summary(),
            compute_time: self.compute_time.summary(),
            mean_reuse_ratio: self.reuse_ratio.mean(),
            io: self.io,
        }
    }
}

/// Summary report of one run.
#[derive(Clone, Copy, Debug)]
pub struct Report {
    pub finished: usize,
    pub ttft: Summary,
    pub e2el: Summary,
    pub itl: Summary,
    pub queue_time: Summary,
    pub compute_time: Summary,
    pub mean_reuse_ratio: f64,
    /// Dual-lane transfer counters (demand vs prefetch, upgrades).
    pub io: IoStats,
}

impl Report {
    /// Multi-line human-readable block (seconds).
    pub fn pretty(&self) -> String {
        let mut s = format!(
            "finished={} reuse={:.1}%\n  TTFT  {}\n  E2EL  {}\n  ITL   {}\n  queue {}\n  comp  {}",
            self.finished,
            self.mean_reuse_ratio * 100.0,
            self.ttft.row(1.0),
            self.e2el.row(1.0),
            self.itl.row(1.0),
            self.queue_time.row(1.0),
            self.compute_time.row(1.0),
        );
        if self.io.demand.submitted + self.io.prefetch.submitted > 0 {
            s.push_str("\n  ");
            s.push_str(&self.io.pretty().replace('\n', "\n  "));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::ChunkedSeq;
    use std::sync::Arc;

    fn finished_request(arrival: f64, ttft: f64, e2e: f64) -> Request {
        let tokens: Vec<u32> = (0..512).collect();
        let chain = ChunkedSeq::new(&tokens, 256);
        let mut r = Request::new(0, 0, tokens.into(), Arc::new(chain), 4,
                                 arrival, arrival + 0.01);
        r.started_at = Some(arrival + 0.5);
        r.first_token_at = Some(arrival + ttft);
        r.finished_at = Some(arrival + e2e);
        r.itl = vec![0.02, 0.03, 0.025];
        r.reused_tokens = 256;
        r.computed_tokens = 256;
        r
    }

    #[test]
    fn collects_and_summarizes() {
        let mut m = MetricsCollector::new();
        for i in 0..10 {
            m.record(&finished_request(i as f64, 1.0 + i as f64 * 0.1, 2.0));
        }
        let rep = m.report();
        assert_eq!(rep.finished, 10);
        assert!((rep.ttft.mean - 1.45).abs() < 1e-9);
        assert_eq!(rep.itl.n, 30);
        assert!((rep.mean_reuse_ratio - 0.5).abs() < 1e-9);
        assert!(rep.pretty().contains("TTFT"));
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = MetricsCollector::new();
        for i in 0..100 {
            m.record(&finished_request(i as f64, 0.5 + (i % 17) as f64 * 0.2, 3.0));
        }
        let rep = m.report();
        assert!(rep.ttft.p50 <= rep.ttft.p95);
        assert!(rep.ttft.p95 <= rep.ttft.p99);
    }
}
