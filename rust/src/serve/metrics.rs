//! Serving metrics: TTFT / E2EL / ITL / queueing collectors and the
//! per-run report every bench prints (the paper's Figs 14–16 rows).

use crate::io::IoStats;
use crate::obs::breakdown::{BreakdownSummary, TtftAttribution};
use crate::serve::request::Request;
use crate::util::stats::{Samples, Summary};

/// Graceful-degradation counters: how often the cache path *failed
/// to accelerate* and fell back to the always-correct recompute path
/// (see the failure model in [`crate::io`]). All zero on a healthy
/// run; under fault injection the chaos proptest reconciles these
/// against the injection session's own counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DegradeStats {
    /// SSD loads that degraded to recompute (lost + corrupted +
    /// retries-exhausted chunks).
    pub degraded_loads: u64,
    /// Chunks evicted because their stored bytes were unreadable.
    pub quarantined_chunks: u64,
    /// Retry attempts spent on transient read errors.
    pub retries: u64,
    /// Requests re-routed off a failed replica (cluster runs only).
    pub failovers: u64,
    /// Store-level syscall errors absorbed (fsync, delete, lost files,
    /// checksum sweeps) — surfaced from `StoreStats` on the real path.
    pub store_errors: u64,
}

impl DegradeStats {
    pub fn any(&self) -> bool {
        self.degraded_loads + self.quarantined_chunks + self.retries + self.failovers
            + self.store_errors
            > 0
    }

    pub fn absorb(&mut self, other: &DegradeStats) {
        self.degraded_loads += other.degraded_loads;
        self.quarantined_chunks += other.quarantined_chunks;
        self.retries += other.retries;
        self.failovers += other.failovers;
        self.store_errors += other.store_errors;
    }
}

/// All samples collected over one serving run.
#[derive(Clone, Debug, Default)]
pub struct MetricsCollector {
    pub ttft: Samples,
    pub e2el: Samples,
    pub itl: Samples,
    pub queue_time: Samples,
    pub compute_time: Samples,
    pub retrieval_time: Samples,
    /// Per-request reuse ratio (reused / total tokens).
    pub reuse_ratio: Samples,
    pub finished: usize,
    /// Transfer-lane counters (set by the engine before `report`).
    pub io: IoStats,
    /// Graceful-degradation counters (all zero on a healthy run).
    pub degrade: DegradeStats,
    /// Per-prefill TTFT attribution rows (always recorded — the
    /// stage split is exact and costs one push per prefill).
    pub attribution: TtftAttribution,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ingest a finished request.
    pub fn record(&mut self, r: &Request) {
        debug_assert!(r.finished_at.is_some());
        if let Some(x) = r.ttft() {
            self.ttft.push(x);
        }
        if let Some(x) = r.e2el() {
            self.e2el.push(x);
        }
        if let Some(x) = r.queue_time() {
            self.queue_time.push(x);
        }
        if let Some(x) = r.compute_time() {
            self.compute_time.push(x);
        }
        for &gap in &r.itl {
            self.itl.push(gap);
        }
        // a request is queued the moment retrieval delivers its
        // documents, so queued-at − arrival IS the retrieval stage
        self.retrieval_time.push(r.queued_at - r.arrival);
        let total = r.total_tokens().max(1);
        self.reuse_ratio
            .push(r.reused_tokens as f64 / total as f64);
        self.finished += 1;
    }

    /// Surface store-layer error counts (the real path's `StoreStats`
    /// totals) so they appear in the degradation block of the report.
    /// Takes the running total — call once, right before `report`.
    pub fn record_store_errors(&mut self, total: u64) {
        self.degrade.store_errors = total;
    }

    /// Merge another collector's samples and counters into this one —
    /// the cluster aggregation path (per-replica collectors fold into
    /// one fleet-wide report).
    pub fn absorb(&mut self, other: &MetricsCollector) {
        self.ttft.extend_from(&other.ttft);
        self.e2el.extend_from(&other.e2el);
        self.itl.extend_from(&other.itl);
        self.queue_time.extend_from(&other.queue_time);
        self.compute_time.extend_from(&other.compute_time);
        self.retrieval_time.extend_from(&other.retrieval_time);
        self.reuse_ratio.extend_from(&other.reuse_ratio);
        self.finished += other.finished;
        self.io.absorb(&other.io);
        self.degrade.absorb(&other.degrade);
        self.attribution.absorb(&other.attribution);
    }

    pub fn report(&mut self) -> Report {
        Report {
            finished: self.finished,
            ttft: self.ttft.summary(),
            e2el: self.e2el.summary(),
            itl: self.itl.summary(),
            queue_time: self.queue_time.summary(),
            compute_time: self.compute_time.summary(),
            retrieval: self.retrieval_time.summary(),
            mean_reuse_ratio: self.reuse_ratio.mean(),
            io: self.io,
            degrade: self.degrade,
            ttft_breakdown: self.attribution.summary(),
        }
    }
}

/// Summary report of one run.
#[derive(Clone, Copy, Debug)]
pub struct Report {
    pub finished: usize,
    pub ttft: Summary,
    pub e2el: Summary,
    pub itl: Summary,
    pub queue_time: Summary,
    pub compute_time: Summary,
    /// Retrieval-stage latency (arrival → documents ready).
    pub retrieval: Summary,
    pub mean_reuse_ratio: f64,
    /// Dual-lane transfer counters (demand vs prefetch, upgrades).
    pub io: IoStats,
    /// Graceful-degradation counters (all zero on a healthy run).
    pub degrade: DegradeStats,
    /// Mean TTFT attribution over all prefills (paper Table 1 analog).
    pub ttft_breakdown: BreakdownSummary,
}

impl Report {
    /// Multi-line human-readable block (seconds).
    pub fn pretty(&self) -> String {
        let mut s = format!(
            "finished={} reuse={:.1}%\n  TTFT  {}\n  E2EL  {}\n  ITL   {}\n  queue {}\n  comp  {}\n  retr  {}",
            self.finished,
            self.mean_reuse_ratio * 100.0,
            self.ttft.row(1.0),
            self.e2el.row(1.0),
            self.itl.row(1.0),
            self.queue_time.row(1.0),
            self.compute_time.row(1.0),
            self.retrieval.row(1.0),
        );
        if self.io.demand.submitted + self.io.prefetch.submitted > 0 {
            s.push_str("\n  ");
            s.push_str(&self.io.pretty().replace('\n', "\n  "));
        }
        if self.degrade.any() {
            let d = &self.degrade;
            s.push_str(&format!(
                "\n  degrade loads={} quarantined={} retries={} failovers={} store_errors={}",
                d.degraded_loads, d.quarantined_chunks, d.retries, d.failovers, d.store_errors
            ));
        }
        if self.ttft_breakdown.any() {
            s.push_str("\n  ");
            s.push_str(&self.ttft_breakdown.pretty());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::chunk::ChunkedSeq;
    use std::sync::Arc;

    fn finished_request(arrival: f64, ttft: f64, e2e: f64) -> Request {
        let tokens: Vec<u32> = (0..512).collect();
        let chain = ChunkedSeq::new(&tokens, 256);
        let mut r = Request::new(0, 0, tokens.into(), Arc::new(chain), 4,
                                 arrival, arrival + 0.01);
        r.started_at = Some(arrival + 0.5);
        r.first_token_at = Some(arrival + ttft);
        r.finished_at = Some(arrival + e2e);
        r.itl = vec![0.02, 0.03, 0.025];
        r.reused_tokens = 256;
        r.computed_tokens = 256;
        r
    }

    #[test]
    fn collects_and_summarizes() {
        let mut m = MetricsCollector::new();
        for i in 0..10 {
            m.record(&finished_request(i as f64, 1.0 + i as f64 * 0.1, 2.0));
        }
        let rep = m.report();
        assert_eq!(rep.finished, 10);
        assert!((rep.ttft.mean - 1.45).abs() < 1e-9);
        assert_eq!(rep.itl.n, 30);
        assert!((rep.mean_reuse_ratio - 0.5).abs() < 1e-9);
        assert!(rep.pretty().contains("TTFT"));
        assert!(rep.pretty().contains("retr"));
    }

    #[test]
    fn record_populates_retrieval_time() {
        // regression: `record` used to drop the retrieval stage on the
        // floor, leaving `retrieval_time` permanently empty
        let mut m = MetricsCollector::new();
        for i in 0..10 {
            m.record(&finished_request(i as f64, 1.0, 2.0));
        }
        assert_eq!(m.retrieval_time.len(), 10);
        let rep = m.report();
        assert_eq!(rep.retrieval.n, 10);
        // finished_request queues each request 10 ms after arrival
        assert!((rep.retrieval.mean - 0.01).abs() < 1e-9);
        assert!((rep.retrieval.max - 0.01).abs() < 1e-9);
    }

    #[test]
    fn absorb_merges_collectors() {
        let mut a = MetricsCollector::new();
        let mut b = MetricsCollector::new();
        for i in 0..4 {
            a.record(&finished_request(i as f64, 1.0, 2.0));
            b.record(&finished_request(i as f64, 2.0, 3.0));
        }
        a.io.upgraded = 3;
        b.io.upgraded = 4;
        b.io.demand.submitted = 7;
        a.absorb(&b);
        let rep = a.report();
        assert_eq!(rep.finished, 8);
        assert_eq!(rep.ttft.n, 8);
        assert!((rep.ttft.mean - 1.5).abs() < 1e-9);
        assert_eq!(rep.retrieval.n, 8);
        assert_eq!(rep.io.upgraded, 7);
        assert_eq!(rep.io.demand.submitted, 7);
    }

    #[test]
    fn degrade_counters_merge_and_print_only_when_nonzero() {
        let mut a = MetricsCollector::new();
        let mut b = MetricsCollector::new();
        a.record(&finished_request(0.0, 1.0, 2.0));
        b.record(&finished_request(1.0, 1.0, 2.0));
        assert!(!a.report().degrade.any());
        assert!(!a.report().pretty().contains("degrade"));
        b.degrade.degraded_loads = 3;
        b.degrade.quarantined_chunks = 2;
        b.degrade.retries = 5;
        b.degrade.failovers = 1;
        a.record_store_errors(4);
        a.absorb(&b);
        let rep = a.report();
        assert_eq!(rep.degrade.degraded_loads, 3);
        assert_eq!(rep.degrade.quarantined_chunks, 2);
        assert_eq!(rep.degrade.retries, 5);
        assert_eq!(rep.degrade.failovers, 1);
        assert_eq!(rep.degrade.store_errors, 4);
        assert!(rep.degrade.any());
        assert!(rep.pretty().contains("degrade loads=3"));
    }

    #[test]
    fn breakdown_block_prints_and_absorbs() {
        use crate::obs::breakdown::RequestBreakdown;
        let row = RequestBreakdown {
            request: 0,
            retrieval: 0.01,
            queue: 0.2,
            load_stall: 0.05,
            compute: 0.7,
            exposed: 0.04,
            hidden: 0.1,
            ttft: 1.0,
        };
        let mut a = MetricsCollector::new();
        a.record(&finished_request(0.0, 1.0, 2.0));
        assert!(!a.report().pretty().contains("ttft ="), "no rows, no block");
        let mut b = MetricsCollector::new();
        b.attribution.record(row);
        a.absorb(&b);
        let rep = a.report();
        assert!(rep.ttft_breakdown.any());
        assert_eq!(rep.ttft_breakdown.n, 1);
        assert!((rep.ttft_breakdown.ttft - 1.0).abs() < 1e-12);
        assert!(rep.pretty().contains("ttft ="));
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = MetricsCollector::new();
        for i in 0..100 {
            m.record(&finished_request(i as f64, 0.5 + (i % 17) as f64 * 0.2, 3.0));
        }
        let rep = m.report();
        assert!(rep.ttft.p50 <= rep.ttft.p95);
        assert!(rep.ttft.p95 <= rep.ttft.p99);
    }
}
