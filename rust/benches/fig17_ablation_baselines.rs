//! Fig 17 — prefill latency: PCR vs the simplified baselines
//! (vLLM / CCache / SCCache) across models and rates, plus an
//! eviction-policy sweep pitting the registered policies (including the
//! new SLRU / 2Q / LFUDA family) against the paper baselines on the
//! same workload.
//!
//! Paper's shapes: tiers help (CCache ≥ vLLM, SCCache ≥ CCache in hit
//! ratio) BUT SCCache is *not* universally faster than CCache — for
//! big-KV models the synchronous SSD loads can cost more than the
//! recompute they replace. PCR wins everywhere; its biggest margin over
//! SCCache sits at middle rates.

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::cache::policy::registry;
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    let scale = Scale::from_env();
    section("Fig 17: PCR vs simplified baselines (prefill latency / TTFT)");
    let models = ["qwen2.5-7b", "qwen2.5-14b", "llama2-7b", "llama2-13b"];
    for model in models {
        println!("\nmodel = {model}");
        let mut t = Table::new(&[
            "rate", "vllm", "ccache", "sccache", "pcr", "pcr-vs-sccache",
        ]);
        let mut reductions = Vec::new();
        for rate in [0.5, 0.75, 1.0] {
            let cfg = paper_config(model, "a6000", true, rate, scale);
            let wl = Workload::build(&cfg);
            let run = |name: &str| {
                let spec = SystemSpec::named(name, cfg.prefetch_window).unwrap();
                engine::run(&cfg, &spec, &wl).report.ttft.mean
            };
            let vllm = run("vllm");
            let cc = run("ccache");
            let scc = run("sccache");
            let pcr = run("pcr");
            let red = 100.0 * (1.0 - pcr / scc);
            reductions.push((rate, red));
            t.row(&[
                format!("{rate:.2}"),
                fmt_secs(vllm),
                fmt_secs(cc),
                fmt_secs(scc),
                fmt_secs(pcr),
                format!("-{red:.1}%"),
            ]);
            assert!(cc <= vllm * 1.05, "{model}: CPU tier should help");
            assert!(pcr <= scc * 1.001, "{model}: PCR must beat SCCache");
        }
        t.print();
        let avg = reductions.iter().map(|(_, r)| r).sum::<f64>()
            / reductions.len() as f64;
        println!("PCR vs SCCache average TTFT reduction: {avg:.1}% \
                  (paper: 36.4% llama2-7b, 50.9% 13b, 3.9% qwen-7b, 14.2% 14b)");
    }

    policy_sweep(scale);
}

/// Eviction-policy sweep: every registered policy on the PCR backbone
/// vs the paper baselines (vLLM/SCCache anchors included for scale),
/// one model, middle rate — the hit-ratio/TTFT comparison for the new
/// SLRU / 2Q / LFUDA family.
fn policy_sweep(scale: Scale) {
    section("Fig 17b: eviction-policy sweep (PCR backbone, llama2-7b @ 0.75 req/s)");
    let cfg = paper_config("llama2-7b", "a6000", true, 0.75, scale);
    let wl = Workload::build(&cfg);

    let mut t = Table::new(&["arm", "ttft-mean", "ttft-p99", "hit%", "vs lru"]);
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for name in ["vllm", "sccache"] {
        let spec = SystemSpec::named(name, cfg.prefetch_window).unwrap();
        let out = engine::run(&cfg, &spec, &wl);
        rows.push((
            format!("baseline:{name}"),
            out.report.ttft.mean,
            out.report.ttft.p99,
            out.cache.hit_ratio(),
        ));
    }
    let mut lru_ttft = f64::NAN;
    for name in registry::NAMES {
        let spec = SystemSpec::named("pcr", cfg.prefetch_window)
            .unwrap()
            .with_overrides(name, "");
        let out = engine::run(&cfg, &spec, &wl);
        if name == "lru" {
            lru_ttft = out.report.ttft.mean;
        }
        rows.push((
            format!("pcr:{name}"),
            out.report.ttft.mean,
            out.report.ttft.p99,
            out.cache.hit_ratio(),
        ));
    }
    assert!(lru_ttft.is_finite(), "lru arm present");
    for (name, mean, p99, hit) in &rows {
        t.row(&[
            name.clone(),
            fmt_secs(*mean),
            fmt_secs(*p99),
            format!("{:.1}", hit * 100.0),
            format!("{:+.1}%", 100.0 * (mean / lru_ttft - 1.0)),
        ]);
    }
    t.print();
    println!(
        "(queue-aware arms run with the look-ahead boost pass on; \
         all arms share tiers, overlap, window {})",
        cfg.prefetch_window
    );
}
