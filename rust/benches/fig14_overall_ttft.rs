//! Fig 14 — THE headline result: TTFT across models × platforms ×
//! workloads × request rates, PCR vs vLLM vs LMCache.
//!
//! Expected shape (paper): PCR fastest everywhere; LMCache between PCR
//! and vLLM; TTFT grows with rate but PCR's curve is flattest; speedups
//! in the 1.4–2.5x band at higher rates (paper: 2.13x/1.42x at base
//! rates rising to 2.47x/1.59x).

use pcr::bench::scenario::{paper_config, paper_models, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    let scale = Scale::from_env();
    section("Fig 14: overall TTFT (PCR vs vLLM vs LMCache)");
    let rates = [0.5, 0.75, 1.0];
    let mut all_speedups: Vec<f64> = Vec::new();
    for workload1 in [true, false] {
        let wname = if workload1 { "workload1" } else { "workload2" };
        for model in paper_models(scale) {
            for platform in ["a6000", "rtx4090"] {
                println!("\n--- {model} on {platform}, {wname} ---");
                let mut t = Table::new(&[
                    "rate", "vllm", "lmcache", "pcr", "pcr-vs-vllm", "pcr-vs-lmcache",
                ]);
                for rate in rates {
                    let cfg = paper_config(model, platform, workload1, rate, scale);
                    let wl = Workload::build(&cfg);
                    let run = |name: &str| {
                        let spec = SystemSpec::named(name, cfg.prefetch_window).unwrap();
                        engine::run(&cfg, &spec, &wl).report.ttft.mean
                    };
                    let vllm = run("vllm");
                    let lmc = run("lmcache");
                    let pcr = run("pcr");
                    all_speedups.push(vllm / pcr);
                    t.row(&[
                        format!("{rate:.2}"),
                        fmt_secs(vllm),
                        fmt_secs(lmc),
                        fmt_secs(pcr),
                        format!("{:.2}x", vllm / pcr),
                        format!("{:.2}x", lmc / pcr),
                    ]);
                    assert!(pcr <= vllm, "PCR must beat vLLM ({model}@{platform} r={rate})");
                }
                t.print();
            }
        }
    }
    let max = all_speedups.iter().copied().fold(0.0, f64::max);
    let mean = all_speedups.iter().sum::<f64>() / all_speedups.len() as f64;
    println!(
        "\nPCR speedup over vLLM: mean {mean:.2}x, max {max:.2}x \
         (paper: up to 2.47x; average ~15% over the best baseline)"
    );
}
