//! Fig 11 — queueing vs computing time under load.
//!
//! Paper's point: at higher request rates requests spend much longer
//! *waiting* than computing — idle time the queue-based prefetcher
//! turns into useful SSD→DRAM transfers.

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    section("Fig 11: queueing vs computing time");
    let scale = Scale::from_env();
    for model in ["qwen2.5-14b", "llama2-13b"] {
        println!("\nmodel = {model}");
        let mut t = Table::new(&[
            "rate", "queue-mean", "compute-mean", "queue/compute", "queue-p99",
        ]);
        let mut ratios = Vec::new();
        for rate in [0.5, 0.75, 1.0] {
            let cfg = paper_config(model, "a6000", true, rate, scale);
            let wl = Workload::build(&cfg);
            // measure on the *base* system (no prefetch) so the queueing
            // opportunity itself is what we see
            let spec = SystemSpec::pcr_base();
            let out = engine::run(&cfg, &spec, &wl);
            let ratio = out.report.queue_time.mean / out.report.compute_time.mean;
            ratios.push(ratio);
            t.row(&[
                format!("{rate:.2}"),
                fmt_secs(out.report.queue_time.mean),
                fmt_secs(out.report.compute_time.mean),
                format!("{ratio:.1}x"),
                fmt_secs(out.report.queue_time.p99),
            ]);
        }
        t.print();
        assert!(
            ratios.last().unwrap() > ratios.first().unwrap(),
            "queueing share must grow with load"
        );
    }
    println!("\nunder heavy load requests wait far longer than they compute —\nexactly the window §4.4's prefetcher uses.");
}
