//! Fig 9 — per-layer load vs compute across the computed-token ratio.
//!
//! For a fixed 8192-token context, as the *computed* fraction shrinks
//! (more reuse), per-layer compute time falls while per-layer load time
//! grows. The paper's claim (§4.3): thanks to PCIe bandwidth, loading
//! stays below compute even at 80% reuse (20% computed), so layer-wise
//! overlap hides it. We print the full sweep and the crossover.

use pcr::bench::{section, Table};
use pcr::hw::gpu::GpuCostModel;
use pcr::hw::spec::{model_spec, platform_spec};
use pcr::hw::transfer::TransferFabric;
use pcr::sim::pipeline::{makespan, LayerTimings, OverlapMode};

fn main() {
    section("Fig 9: load vs compute across computed ratio (8192-token context)");
    let ctx = 8192u64;
    let platform = platform_spec("a6000").unwrap();
    for name in ["qwen2.5-14b", "llama2-13b"] {
        let model = model_spec(name).unwrap();
        let gpu = GpuCostModel::new(&model, &platform);
        let fabric = TransferFabric::new(&platform);
        let layers = model.n_layers as usize;
        println!("\nmodel = {name}");
        let mut t = Table::new(&[
            "computed%", "load/layer", "compute/layer", "pipe(updown)", "pipe(sync)",
        ]);
        let mut crossover: Option<u64> = None;
        for computed_pct in [100u64, 80, 60, 40, 20, 10] {
            let computed = ctx * computed_pct / 100;
            let reused = ctx - computed;
            let load_bytes = model.kv_bytes_per_token() * reused;
            let load_per_layer = fabric.h2d.copy_time(load_bytes / layers as u64);
            let compute_per_layer = gpu.prefill_time(reused, computed) / layers as f64;
            let down_bytes = model.kv_bytes_per_token() * computed;
            let down_per_layer = fabric.d2h.copy_time(down_bytes / layers as u64);
            let timings = LayerTimings {
                up: vec![load_per_layer; layers],
                compute: vec![compute_per_layer; layers],
                down: vec![down_per_layer; layers],
                sync_overhead: 0.0,
            };
            t.row(&[
                format!("{computed_pct}"),
                format!("{:.2} ms", load_per_layer * 1e3),
                format!("{:.2} ms", compute_per_layer * 1e3),
                format!("{:.3} s", makespan(&timings, OverlapMode::UpDown)),
                format!("{:.3} s", makespan(&timings, OverlapMode::Sync)),
            ]);
            if load_per_layer > compute_per_layer && crossover.is_none() {
                crossover = Some(computed_pct);
            }
        }
        t.print();
        match crossover {
            Some(p) => println!("load exceeds compute below {p}% computed — overlap \
                                 stops hiding the upload there"),
            None => println!("load stays below compute across the whole sweep \
                              (paper's §4.3 claim holds)"),
        }
    }
}
