//! Fig 16 — percentile scalability of PCR across request rates.
//!
//! Paper's shape: all percentiles grow smoothly and monotonically with
//! rate (no cliffs); P50 stays low; the P75–P90 gap stays narrow; P99
//! grows moderately (controlled tail).

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    let scale = Scale::from_env();
    section("Fig 16: PCR latency percentiles vs request rate (llama3.1-8b)");
    for metric in ["TTFT", "E2EL", "ITL"] {
        println!("\nmetric = {metric}");
        let mut t = Table::new(&["rate", "p50", "p75", "p90", "p95", "p99"]);
        let mut p99_series = Vec::new();
        for rate in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
            let cfg = paper_config("llama3.1-8b", "rtx4090", true, rate, scale);
            let wl = Workload::build(&cfg);
            let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
            let out = engine::run(&cfg, &spec, &wl);
            let s = match metric {
                "TTFT" => out.report.ttft,
                "E2EL" => out.report.e2el,
                _ => out.report.itl,
            };
            p99_series.push(s.p99);
            t.row(&[
                format!("{rate:.1}"),
                fmt_secs(s.p50),
                fmt_secs(s.p75),
                fmt_secs(s.p90),
                fmt_secs(s.p95),
                fmt_secs(s.p99),
            ]);
        }
        t.print();
        // smooth monotone-ish growth: no >8x cliff between neighbours
        for w in p99_series.windows(2) {
            assert!(w[1] < w[0] * 8.0 + 1e-6, "p99 cliff detected: {w:?}");
        }
    }
    println!("\nsmooth, monotone growth across rates — no saturation cliff\n(the paper's 'robust system behaviour' claim).");
}
