//! Table 1 — performance breakdown: base → +overlap → +prefetch.
//!
//! Cumulative arms on four models at low (0.5) and high (1.0) rates.
//! Paper's shape: overlap is the bigger single win on average (~15%);
//! Llama (MHA, big KV) gains much more than Qwen (GQA, small KV);
//! prefetch adds more at the high rate (deeper queue = more look-ahead).

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;

fn main() {
    let scale = Scale::from_env();
    section("Table 1: breakdown — base / +overlap / +prefetch");
    let models = ["qwen2.5-7b", "qwen2.5-14b", "llama2-7b", "llama2-13b"];
    let mut t = Table::new(&[
        "model", "rate", "base", "+overlap", "red%", "+prefetch", "red%",
    ]);
    let mut llama_high_red = 0.0f64;
    let mut qwen_high_red = 0.0f64;
    for model in models {
        for rate in [0.5, 1.0] {
            let cfg = paper_config(model, "a6000", true, rate, scale);
            let wl = Workload::build(&cfg);
            let run = |spec: SystemSpec| engine::run(&cfg, &spec, &wl).report.ttft.mean;
            let base = run(SystemSpec::pcr_base());
            let overlap = run(SystemSpec::pcr_overlap());
            let full = run(SystemSpec::named("pcr", cfg.prefetch_window).unwrap());
            let red_o = 100.0 * (1.0 - overlap / base);
            let red_f = 100.0 * (1.0 - full / base);
            t.row(&[
                model.to_string(),
                format!("{rate:.1}"),
                format!("{base:.3} s"),
                format!("{overlap:.3} s"),
                format!("{red_o:.1}"),
                format!("{full:.3} s"),
                format!("{red_f:.1}"),
            ]);
            assert!(overlap <= base * 1.001, "{model}: overlap must not hurt");
            assert!(full <= overlap * 1.02, "{model}: prefetch must not hurt");
            if rate == 1.0 {
                if model.starts_with("llama2") {
                    llama_high_red = llama_high_red.max(red_f);
                } else {
                    qwen_high_red = qwen_high_red.max(red_f);
                }
            }
        }
    }
    t.print();
    println!(
        "\nLlama2 (MHA, large KV) best high-rate reduction: {llama_high_red:.1}% \
         vs Qwen2.5 (GQA): {qwen_high_red:.1}% — the paper's KV-size contrast \
         (its Table 1: Llama2-7B -69%, Qwen2.5-7B -6%)."
    );
    assert!(
        llama_high_red > qwen_high_red,
        "MHA models must benefit more than GQA models"
    );
}
