//! §Perf — hot-path microbenchmarks of the L3 coordinator.
//!
//! These are the before/after probes for the optimization pass recorded
//! in EXPERIMENTS.md §Perf: prefix-tree matching, eviction-candidate
//! scans, the eviction-pressure A/B of the fused O(n) scan vs the
//! indexed O(log n) heap (§Perf iteration 3 — emitted as machine-
//! readable `BENCH_eviction_pressure.json`), movement planning,
//! pipeline makespan, a full engine step, the substrate hot spots
//! (HNSW search, JSON, PRNG), the dual-lane transfer engine's
//! demand-vs-prefetch contention on real disk (Fig 12), and the
//! cluster router sweep (§Perf iteration 4 — routing policy ×
//! replica count, emitted as `BENCH_cluster_routing.json`).
//!
//! Plus the §Robustness fault-injection sweep (fault rate × TTFT ×
//! degradation counters, and the checksum overhead of the integrity
//! trailer on the fault-free path — emitted as
//! `BENCH_fault_injection.json`), and the §Observability TTFT
//! attribution table (per-stage breakdown per system + the tracing
//! overhead gate — emitted as `BENCH_ttft_breakdown.json`).
//!
//! Args (after `cargo bench --bench perf_hotpath --`):
//!   --eviction-pressure   run only the eviction-pressure section
//!   --cluster-routing     run only the cluster router sweep
//!   --fault-sweep         run only the fault-injection sweep
//!   --ttft-breakdown      run only the TTFT attribution section
//!   --smoke               small trees + short timing (CI smoke mode)

use pcr::bench::{black_box, section, Bench};
use pcr::cache::chunk::{chain_hash, ChunkKey, ChunkedSeq};
use pcr::cache::engine::{CacheConfig, CacheEngine};
use pcr::cache::policy::registry;
use pcr::cache::tier::Tier;
use pcr::sim::pipeline::{makespan, LayerTimings, OverlapMode};
use pcr::util::json::Json;
use pcr::util::rng::Rng;

fn build_cache(chains: usize, depth: usize) -> (CacheEngine, Vec<Vec<ChunkKey>>) {
    let mut cache = CacheEngine::new(CacheConfig {
        chunk_tokens: 256,
        gpu_capacity: u64::MAX / 4,
        dram_capacity: u64::MAX / 4,
        ssd_capacity: u64::MAX / 4,
        policy: "lookahead-lru".into(),
    });
    let mut all = Vec::new();
    for c in 0..chains {
        let mut keys = Vec::new();
        let mut parent_key = ChunkKey::ROOT;
        let mut parent = None;
        for i in 0..depth {
            let k = chain_hash(parent_key, &[c as u32, i as u32]);
            parent = cache.insert(parent, k, 1_000_000, Tier::Dram);
            keys.push(k);
            parent_key = k;
        }
        all.push(keys);
    }
    (cache, all)
}

/// One steady-state cache under eviction pressure: `n` independent
/// DRAM leaves at exact capacity, then evict_one + insert per op (each
/// eviction frees exactly the slot the next insert needs, so the tree
/// holds `n` live nodes throughout). Returns (evictions/sec,
/// stale_discarded, compactions).
fn pressure_rate(n: usize, indexed: bool, min_time: f64) -> (f64, u64, u64) {
    const CB: u64 = 1_000_000;
    let mut cache = CacheEngine::new(CacheConfig {
        chunk_tokens: 256,
        gpu_capacity: 0,
        dram_capacity: n as u64 * CB,
        ssd_capacity: 0,
        policy: "lookahead-lru".into(),
    });
    cache.use_indexed_eviction = indexed;
    for i in 0..n {
        let k = chain_hash(ChunkKey::ROOT, &[0xBEEF, i as u32]);
        cache.insert(None, k, CB, Tier::Dram).expect("seed insert");
    }
    let mut fresh = 0u32;
    let mut ops = 0u64;
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs_f64() < min_time {
        for _ in 0..200 {
            black_box(cache.evict_one(Tier::Dram)).expect("nonempty tier");
            let k = chain_hash(ChunkKey::ROOT, &[0xF00D, fresh]);
            fresh = fresh.wrapping_add(1);
            cache.insert(None, k, CB, Tier::Dram).expect("steady insert");
            ops += 1;
        }
    }
    let rate = ops as f64 / t0.elapsed().as_secs_f64();
    (rate, cache.victim_index.stale_discarded, cache.victim_index.compactions)
}

/// The §Perf iteration 3 headline probe: evictions/sec under insert
/// pressure, fused scan vs incremental index, across tree sizes. The
/// fused path is O(n) per eviction, the indexed path amortized
/// O(log n) — the gap must widen with n. Emits
/// `BENCH_eviction_pressure.json` next to the manifest (CI uploads it
/// as an artifact; EXPERIMENTS.md tracks the trajectory).
fn eviction_pressure(smoke: bool) {
    section("perf: eviction pressure — fused O(n) scan vs indexed lazy rank heap");
    let (sizes, min_time): (&[usize], f64) = if smoke {
        (&[1_000, 4_000], 0.25)
    } else {
        (&[1_000, 10_000, 100_000], 1.0)
    };
    let mut rows: Vec<Json> = Vec::new();
    for &n in sizes {
        let (fused, _, _) = pressure_rate(n, false, min_time);
        let (indexed, stale, compactions) = pressure_rate(n, true, min_time);
        let speedup = indexed / fused;
        println!(
            "  {n:>7} nodes: fused {fused:>10.0} ev/s, indexed {indexed:>10.0} ev/s ({speedup:.1}x)"
        );
        rows.push(Json::from_pairs(vec![
            ("nodes", n.into()),
            ("fused_evictions_per_sec", fused.into()),
            ("indexed_evictions_per_sec", indexed.into()),
            ("speedup", speedup.into()),
            ("stale_discarded", stale.into()),
            ("compactions", compactions.into()),
        ]));
    }
    let doc = Json::from_pairs(vec![
        ("bench", "eviction_pressure".into()),
        ("policy", "lookahead-lru".into()),
        ("smoke", smoke.into()),
        ("workload", "steady state: evict_one + insert per op, DRAM at capacity".into()),
        ("sizes", rows.into()),
    ]);
    let path = "BENCH_eviction_pressure.json";
    std::fs::write(path, doc.dump() + "\n").expect("write bench json");
    println!("  -> wrote {path}");
}

/// §Perf iteration 4: aggregate cache behaviour of the replica fleet
/// under each routing policy, across fleet sizes. The PR gate: the
/// affinity routers must beat round-robin on aggregate hit ratio at
/// every replica count — repeat traffic sprayed across the fleet
/// (round-robin) rebuilds every hot prefix N times; directory-driven
/// routing sends repeats to the holder. Emits
/// `BENCH_cluster_routing.json` (CI uploads it as an artifact).
fn cluster_routing(smoke: bool) {
    use pcr::cluster::router::registry as routers;
    use pcr::cluster::sim::run_with;
    use pcr::config::ExperimentConfig;
    use pcr::serve::system::SystemSpec;
    use pcr::serve::workload::Workload;
    use pcr::util::fmt_secs;

    section("perf: cluster router sweep — routing policy x replica count");
    let (n_inputs, n_requests) = if smoke { (60, 240) } else { (200, 800) };
    let cfg = ExperimentConfig {
        model: "llama2-7b".into(),
        platform: "a6000".into(),
        system: "pcr".into(),
        n_inputs,
        n_requests,
        oversample: true,
        rate: 1.0,
        n_docs: 400,
        n_topics: 24,
        mean_doc_tokens: 600,
        query_tokens: 48,
        chunk_tokens: 256,
        gpu_bytes: 2 * (1 << 30),
        dram_bytes: 6 * (1 << 30),
        ssd_bytes: 40 * (1 << 30),
        ..Default::default()
    };
    cfg.validate().expect("bench config");
    let wl = Workload::build(&cfg);
    let spec = SystemSpec::try_named("pcr", cfg.prefetch_window).expect("registered system");
    println!(
        "  {} requests over {} inputs, repetition {:.1}%",
        wl.len(),
        wl.n_distinct_inputs,
        wl.repetition_ratio * 100.0
    );
    let mut rows: Vec<Json> = Vec::new();
    for &n in &[2usize, 4, 8] {
        for name in routers::NAMES {
            let out = run_with(&cfg, &spec, &wl, n, routers::parse(name).unwrap());
            println!(
                "  {n} replicas x {name:<18} hit {:>5.1}%  ttft {}  imbalance {:.3}  stale {}",
                out.hit_ratio * 100.0,
                fmt_secs(out.aggregate.ttft.mean),
                out.load_imbalance,
                out.directory_stale
            );
            rows.push(Json::from_pairs(vec![
                ("replicas", n.into()),
                ("router", name.into()),
                ("hit_ratio", out.hit_ratio.into()),
                ("ttft_mean_s", out.aggregate.ttft.mean.into()),
                ("ttft_p99_s", out.aggregate.ttft.p99.into()),
                ("load_imbalance", out.load_imbalance.into()),
                ("directory_stale", out.directory_stale.into()),
                ("directory_entries", out.directory_entries.into()),
            ]));
        }
    }
    let doc = Json::from_pairs(vec![
        ("bench", "cluster_routing".into()),
        ("system", "pcr".into()),
        ("smoke", smoke.into()),
        (
            "workload",
            format!(
                "{} requests over {} inputs, oversampled, rate 1.0 req/s",
                n_requests, n_inputs
            )
            .into(),
        ),
        ("rows", rows.into()),
    ]);
    let path = "BENCH_cluster_routing.json";
    std::fs::write(path, doc.dump() + "\n").expect("write bench json");
    println!("  -> wrote {path}");
}

/// §Robustness: the fault-injection sweep. Two probes:
///
/// 1. Virtual-time serving under increasing fault rates (transient +
///    loss + corruption + spikes all at rate r): every request must
///    still finish, and the degradation counters must reconcile with
///    the injection session's own counts — the bench-level replay of
///    the chaos proptest's invariant, with TTFT/reuse trajectories.
/// 2. The integrity-trailer cost on the *fault-free* real path: wall
///    time of a checksum-verified `FileStore::get` vs the fxhash pass
///    alone. The acceptance gate is overhead < 3% of the demand read.
///
/// Emits `BENCH_fault_injection.json` (CI uploads it as an artifact).
fn fault_sweep(smoke: bool) {
    use pcr::config::ExperimentConfig;
    use pcr::serve::system::SystemSpec;
    use pcr::serve::workload::Workload;
    use pcr::util::fmt_secs;

    section("robustness: fault-injection sweep — TTFT/degradation vs fault rate");
    let (n_inputs, n_requests) = if smoke { (40, 120) } else { (150, 600) };
    let base = ExperimentConfig {
        model: "llama2-7b".into(),
        platform: "a6000".into(),
        system: "pcr".into(),
        n_inputs,
        n_requests,
        oversample: true,
        rate: 0.8,
        n_docs: 150,
        n_topics: 12,
        mean_doc_tokens: 600,
        query_tokens: 48,
        chunk_tokens: 256,
        gpu_bytes: 2 * (1 << 30),
        dram_bytes: 6 * (1 << 30),
        ssd_bytes: 40 * (1 << 30),
        ..Default::default()
    };
    base.validate().expect("bench config");
    let wl = Workload::build(&base);
    let spec = SystemSpec::try_named("pcr", base.prefetch_window).expect("registered system");
    println!(
        "  {} requests over {} inputs, repetition {:.1}%",
        wl.len(),
        wl.n_distinct_inputs,
        wl.repetition_ratio * 100.0
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut clean_ttft = 0.0;
    for &rate in &[0.0f64, 0.01, 0.05, 0.10] {
        let mut cfg = base.clone();
        cfg.fault_transient = rate;
        cfg.fault_loss = rate;
        cfg.fault_corrupt = rate;
        cfg.fault_spike = rate;
        let out = pcr::serve::engine::run(&cfg, &spec, &wl);
        assert_eq!(
            out.report.finished, n_requests,
            "a fault plan must never fail a request"
        );
        let d = out.report.degrade;
        let i = out.injected;
        assert_eq!(d.degraded_loads, i.degrading(), "degradation accounting diverged");
        assert_eq!(d.retries, i.retries, "retry accounting diverged");
        if rate == 0.0 {
            clean_ttft = out.report.ttft.mean;
            assert!(!d.any(), "fault-free run must degrade nothing");
        }
        let ttft_vs_clean = 100.0 * (out.report.ttft.mean / clean_ttft - 1.0);
        println!(
            "  rate {:>4.0}%: ttft {} ({:>+5.1}% vs clean)  reuse {:>5.1}%  \
             degraded {:>3} retries {:>3} spikes {:>3}",
            rate * 100.0,
            fmt_secs(out.report.ttft.mean),
            ttft_vs_clean,
            out.report.mean_reuse_ratio * 100.0,
            d.degraded_loads,
            d.retries,
            i.spikes
        );
        rows.push(Json::from_pairs(vec![
            ("fault_rate", rate.into()),
            ("finished", out.report.finished.into()),
            ("ttft_mean_s", out.report.ttft.mean.into()),
            ("ttft_p99_s", out.report.ttft.p99.into()),
            ("ttft_vs_clean_pct", ttft_vs_clean.into()),
            ("reuse_ratio", out.report.mean_reuse_ratio.into()),
            ("degraded_loads", d.degraded_loads.into()),
            ("quarantined_chunks", d.quarantined_chunks.into()),
            ("retries", d.retries.into()),
            ("injected_lost", i.lost.into()),
            ("injected_corrupted", i.corrupted.into()),
            ("injected_exhausted", i.exhausted.into()),
            ("injected_spikes", i.spikes.into()),
        ]));
    }

    section("robustness: integrity-trailer overhead on the fault-free demand path");
    let (read_ns, checksum_ns, overhead_pct) = {
        use pcr::cache::store::{chunk_checksum, ChunkStore, FileStore};
        let dir = std::env::temp_dir().join(format!("pcr-bench-fault-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = FileStore::new(&dir).expect("temp spill dir");
        let chunk_bytes = 256 * 1024usize;
        let blob = vec![0x5Au8; chunk_bytes];
        let keys: Vec<ChunkKey> =
            (0..64).map(|i| chain_hash(ChunkKey::ROOT, &[9, i as u32])).collect();
        for k in &keys {
            store.put(*k, &blob).expect("seed spill chunk");
        }
        let min_time = if smoke { 0.3 } else { 1.0 };
        let mut i = 0;
        let read = Bench::new("FileStore::get 256 KiB (checksum verified)")
            .min_time(min_time)
            .run(|| {
                let k = keys[i % keys.len()];
                i += 1;
                black_box(store.get(k).unwrap().expect("seeded chunk").len())
            });
        println!("{}", read.line());
        let sum = Bench::new("chunk_checksum 256 KiB (the added work)")
            .min_time(min_time)
            .run(|| black_box(chunk_checksum(&blob)));
        println!("{}", sum.line());
        assert_eq!(store.stats().total(), 0, "probe must not trip error counters");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
        // the verified get = unchecked read + one fxhash pass, so the
        // checksum's share of the demand read is hash / (get - hash)
        let pct = 100.0 * sum.mean_ns / (read.mean_ns - sum.mean_ns).max(1.0);
        println!("  -> checksum overhead: {pct:.2}% of the demand read (gate: < 3%)");
        if pct >= 3.0 {
            println!("  !! overhead above the 3% acceptance gate");
        }
        (read.mean_ns, sum.mean_ns, pct)
    };

    let doc = Json::from_pairs(vec![
        ("bench", "fault_injection".into()),
        ("system", "pcr".into()),
        ("smoke", smoke.into()),
        (
            "workload",
            format!(
                "{} requests over {} inputs, oversampled, rate 0.8 req/s; \
                 transient+loss+corrupt+spike all at fault_rate",
                n_requests, n_inputs
            )
            .into(),
        ),
        ("all_requests_finished", true.into()),
        ("rows", rows.into()),
        (
            "checksum_overhead",
            Json::from_pairs(vec![
                ("read_with_checksum_ns", read_ns.into()),
                ("checksum_ns", checksum_ns.into()),
                ("overhead_pct", overhead_pct.into()),
                ("gate_pct", 3.0.into()),
            ]),
        ),
    ]);
    let path = "BENCH_fault_injection.json";
    std::fs::write(path, doc.dump() + "\n").expect("write bench json");
    println!("  -> wrote {path}");
}

/// §Observability: per-stage TTFT attribution across the evaluated
/// systems — the runnable analog of paper Table 1. Per system it
/// asserts the exact-reconciliation invariant (stages sum to TTFT
/// within 1e-9) and prints/records the mean stage split. Then the
/// tracing cost probe: a traced run must leave virtual time
/// bit-identical, and the ring-sink wall-time overhead on the full
/// engine step is measured against the null-sink path. Emits
/// `BENCH_ttft_breakdown.json` (CI uploads it as an artifact).
fn ttft_breakdown(smoke: bool) {
    use pcr::config::ExperimentConfig;
    use pcr::serve::system::SystemSpec;
    use pcr::serve::workload::Workload;
    use pcr::util::fmt_secs;

    section("obs: TTFT breakdown — retrieval/queue/stall/compute per system");
    let (n_inputs, n_requests) = if smoke { (40, 120) } else { (150, 600) };
    let base = ExperimentConfig {
        model: "llama2-7b".into(),
        platform: "a6000".into(),
        system: "pcr".into(),
        n_inputs,
        n_requests,
        oversample: true,
        rate: 0.8,
        n_docs: 150,
        n_topics: 12,
        mean_doc_tokens: 600,
        query_tokens: 48,
        chunk_tokens: 256,
        gpu_bytes: 2 * (1 << 30),
        dram_bytes: 6 * (1 << 30),
        ssd_bytes: 40 * (1 << 30),
        ..Default::default()
    };
    base.validate().expect("bench config");
    let wl = Workload::build(&base);
    println!(
        "  {} requests over {} inputs, repetition {:.1}%",
        wl.len(),
        wl.n_distinct_inputs,
        wl.repetition_ratio * 100.0
    );
    let mut rows: Vec<Json> = Vec::new();
    for name in SystemSpec::NAMES {
        let spec = SystemSpec::try_named(name, base.prefetch_window).expect("registered system");
        let out = pcr::serve::engine::run(&base, &spec, &wl);
        let residual = out.attribution.max_residual();
        assert!(
            residual < 1e-9,
            "breakdown stages must reconcile with TTFT ({name}: {residual:e})"
        );
        let b = out.report.ttft_breakdown;
        println!(
            "  {name:<8} ttft {}  retr {}  queue {}  stall {}  comp {}  hidden {}",
            fmt_secs(b.ttft),
            fmt_secs(b.retrieval),
            fmt_secs(b.queue),
            fmt_secs(b.load_stall),
            fmt_secs(b.compute),
            fmt_secs(b.hidden),
        );
        let mut row = b.to_json();
        row.set("system", name.into());
        row.set("max_residual", residual.into());
        rows.push(row);
    }

    section("obs: tracing overhead — null sink vs ring sink on the engine step");
    let spec = SystemSpec::try_named("pcr", base.prefetch_window).expect("registered system");
    let mut cfg_on = base.clone();
    cfg_on.obs_trace = true;
    // zero-perturbation gate first: tracing must not move the clock
    let off = pcr::serve::engine::run(&base, &spec, &wl);
    let on = pcr::serve::engine::run(&cfg_on, &spec, &wl);
    assert_eq!(
        off.report.ttft.mean.to_bits(),
        on.report.ttft.mean.to_bits(),
        "tracing must not perturb the virtual clock"
    );
    let reps = if smoke { 3 } else { 10 };
    let time = |cfg: &ExperimentConfig| {
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            black_box(pcr::serve::engine::run(cfg, &spec, &wl).report.finished);
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    let t_off = time(&base);
    let t_on = time(&cfg_on);
    let overhead_pct = 100.0 * (t_on / t_off - 1.0);
    println!(
        "  {} events traced; run {:.1} ms off / {:.1} ms on -> overhead {overhead_pct:+.2}%",
        on.trace.len(),
        t_off * 1e3,
        t_on * 1e3
    );

    let doc = Json::from_pairs(vec![
        ("bench", "ttft_breakdown".into()),
        ("smoke", smoke.into()),
        (
            "workload",
            format!(
                "{} requests over {} inputs, oversampled, rate 0.8 req/s",
                n_requests, n_inputs
            )
            .into(),
        ),
        ("rows", rows.into()),
        (
            "trace_overhead",
            Json::from_pairs(vec![
                ("run_s_trace_off", t_off.into()),
                ("run_s_trace_on", t_on.into()),
                ("overhead_pct", overhead_pct.into()),
                ("events_traced", on.trace.len().into()),
                ("virtual_time_bit_identical", true.into()),
            ]),
        ),
    ]);
    let path = "BENCH_ttft_breakdown.json";
    std::fs::write(path, doc.dump() + "\n").expect("write bench json");
    println!("  -> wrote {path}");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    if args.iter().any(|a| a == "--eviction-pressure") {
        eviction_pressure(smoke);
        return;
    }
    if args.iter().any(|a| a == "--ttft-breakdown") {
        ttft_breakdown(smoke);
        return;
    }
    if args.iter().any(|a| a == "--cluster-routing") {
        cluster_routing(smoke);
        return;
    }
    if args.iter().any(|a| a == "--fault-sweep") {
        fault_sweep(smoke);
        return;
    }

    section("perf: prefix-tree hot path");
    {
        let (cache, chains) = build_cache(2000, 26); // 52k nodes
        let mut i = 0;
        let r = Bench::new("match_chain (26 chunks, 52k-node tree)").run(|| {
            i = (i + 1) % chains.len();
            black_box(cache.tree.match_chain(&chains[i]))
        });
        println!("{}", r.line());
    }
    {
        let (cache, _) = build_cache(2000, 26);
        let r = Bench::new("eviction_candidates scan (52k nodes)")
            .min_time(1.0)
            .run(|| black_box(cache.tree.eviction_candidates(Tier::Dram).len()));
        println!("{}", r.line());
    }
    {
        let r = Bench::new("evict_one under pressure (5k leaves)").min_time(1.0).run_setup();
        println!("{}", r.line());
    }

    eviction_pressure(smoke);

    section("perf: fused victim scan per registered policy (52k nodes)");
    {
        let (cache, _) = build_cache(2000, 26);
        for name in registry::NAMES {
            let policy = registry::parse(name).unwrap();
            let r = Bench::new(format!("pick_victim_fused [{name}]")).run(|| {
                black_box(policy.pick_victim_fused(&cache.tree, Tier::Dram))
            });
            println!("{}", r.line());
        }
    }
    {
        let (mut cache, chains) = build_cache(500, 26);
        let mut i = 0;
        let r = Bench::new("lookup+touch (500x26 chunks)").run(|| {
            i = (i + 1) % chains.len();
            black_box(cache.lookup(&chains[i]).matched_chunks())
        });
        println!("{}", r.line());
    }

    section("perf: chunking + hashing");
    {
        let tokens: Vec<u32> = (0..6800).collect();
        let r = Bench::new("ChunkedSeq::new (6.8k tokens, 256-chunks)").run(|| {
            black_box(ChunkedSeq::new(&tokens, 256).n_chunks())
        });
        println!("{}", r.line());
    }

    section("perf: pipeline makespan");
    {
        let t = LayerTimings::uniform(40, 0.4, 2.0, 0.8, 1e-4);
        let r = Bench::new("makespan up-down (40 layers)").run(|| {
            black_box(makespan(&t, OverlapMode::UpDown))
        });
        println!("{}", r.line());
    }

    section("perf: full engine step throughput");
    {
        use pcr::bench::scenario::{paper_config, Scale};
        use pcr::serve::system::SystemSpec;
        use pcr::serve::workload::Workload;
        let cfg = paper_config("llama3.1-8b", "a6000", true, 1.0, Scale::Lite);
        let wl = Workload::build(&cfg);
        let spec = SystemSpec::named("pcr", 4).unwrap();
        let r = Bench::new(format!("engine::run ({} requests end-to-end)", wl.len()))
            .min_time(2.0)
            .max_iters(50)
            .run(|| black_box(pcr::serve::engine::run(&cfg, &spec, &wl).report.finished));
        println!("{}", r.line());
        println!(
            "  -> {:.0} simulated requests per host-second",
            wl.len() as f64 / (r.mean_ns / 1e9)
        );
    }

    section("perf: substrates");
    {
        let mut rng = Rng::new(1);
        let vectors: Vec<Vec<f32>> = (0..2000)
            .map(|_| (0..128).map(|_| rng.f32()).collect())
            .collect();
        let mut index = pcr::rag::hnsw::Hnsw::new(12, 64, 2);
        for v in &vectors {
            index.insert(v.clone());
        }
        let mut i = 0;
        let r = Bench::new("hnsw search top-2 (2k docs, ef=96)").run(|| {
            i = (i + 1) % vectors.len();
            black_box(index.search(&vectors[i], 2, 96).len())
        });
        println!("{}", r.line());
    }
    {
        let text = r#"{"model":{"layers":32,"heads":[1,2,3]},"ok":true,"x":1.5}"#;
        let r = Bench::new("json parse (small object)").run(|| {
            black_box(pcr::util::json::Json::parse(text).unwrap())
        });
        println!("{}", r.line());
    }
    {
        let mut rng = Rng::new(7);
        let r = Bench::new("rng exponential").run(|| black_box(rng.exponential(0.8)));
        println!("{}", r.line());
    }

    section("perf: tiered-transfer engine on real disk (Fig 12 contention)");
    {
        use pcr::cache::store::{ChunkStore, FileStore};
        use pcr::io::{FetchSource, IoConfig, Lane, TransferEngine};
        use std::sync::{Arc, RwLock};
        use std::time::Duration;

        const TIMEOUT: Duration = Duration::from_secs(10);
        let dir = std::env::temp_dir().join(format!("pcr-bench-io-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = FileStore::new(&dir).expect("temp spill dir");
        let chunk_bytes = 256 * 1024usize;
        let blob = vec![0xA5u8; chunk_bytes];
        // Disjoint key sets so the prefetch flood never dedups against
        // the demand probes: the contention is purely for workers/disk.
        let demand_keys: Vec<ChunkKey> =
            (0..128).map(|i| chain_hash(ChunkKey::ROOT, &[1, i as u32])).collect();
        let prefetch_keys: Vec<ChunkKey> =
            (0..128).map(|i| chain_hash(ChunkKey::ROOT, &[2, i as u32])).collect();
        for k in demand_keys.iter().chain(&prefetch_keys) {
            store.put(*k, &blob).expect("seed spill chunk");
        }
        let source = Arc::new(RwLock::new(store));
        let engine = TransferEngine::new(
            IoConfig { workers: 4, demand_depth: 64, prefetch_depth: 512, ..IoConfig::default() },
            source.clone() as Arc<dyn FetchSource>,
        );

        // (a) demand-fetch latency with an idle prefetch lane
        let mut i = 0;
        let idle = Bench::new("demand fetch 256 KiB (prefetch lane idle)")
            .min_time(1.0)
            .run(|| {
                let k = demand_keys[i % demand_keys.len()];
                i += 1;
                engine.submit(k, Lane::Demand);
                let c = engine.take_blocking(k, TIMEOUT).expect("demand completion");
                black_box(c.data.expect("spill read").len())
            });
        println!("{}", idle.line());

        // (b) same probe while the prefetch lane is saturated: top the
        // queue up with background reads each iteration and let the
        // demand submit cut the line. Fig 12's trade-off — priority
        // keeps the slowdown at "one in-flight read", not "queue depth".
        let mut i = 0;
        let mut j = 0;
        let busy = Bench::new("demand fetch 256 KiB (prefetch lane saturated)")
            .min_time(1.0)
            .run(|| {
                for _ in 0..8 {
                    engine.submit(prefetch_keys[j % prefetch_keys.len()], Lane::Prefetch);
                    j += 1;
                }
                let k = demand_keys[i % demand_keys.len()];
                i += 1;
                engine.submit(k, Lane::Demand);
                let c = engine.take_blocking(k, TIMEOUT).expect("demand completion");
                engine.drain(); // keep the completion queue from pooling
                black_box(c.data.expect("spill read").len())
            });
        println!("{}", busy.line());
        println!(
            "  -> contention slowdown: {:.2}x (demand preempts at queue granularity)",
            busy.mean_ns / idle.mean_ns
        );

        engine.wait_quiescent(TIMEOUT);
        engine.drain();
        let stats = engine.stats();
        println!("  {}", stats.pretty().replace('\n', "\n  "));
        drop(engine);
        drop(source);
        let _ = std::fs::remove_dir_all(&dir);
    }

    cluster_routing(smoke);
    fault_sweep(smoke);
    ttft_breakdown(smoke);
}

/// Helper: eviction benchmark needs per-iteration setup (each eviction
/// consumes a leaf), so it rebuilds in amortized batches.
trait RunSetup {
    fn run_setup(&self) -> pcr::bench::BenchResult;
}

impl RunSetup for Bench {
    fn run_setup(&self) -> pcr::bench::BenchResult {
        // rebuild a 5k-leaf cache, then time draining 4k evictions
        let mut samples = Vec::new();
        let mut total_iters = 0u64;
        let t_start = std::time::Instant::now();
        while t_start.elapsed().as_secs_f64() < 1.0 {
            let (mut cache, _) = build_cache(5000, 1);
            let t0 = std::time::Instant::now();
            for _ in 0..4000 {
                black_box(cache.evict_one(Tier::Dram));
            }
            let dt = t0.elapsed().as_nanos() as f64;
            samples.push(dt / 4000.0);
            total_iters += 4000;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        pcr::bench::BenchResult {
            name: "evict_one under pressure (5k leaves)".into(),
            iters: total_iters,
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
            p50_ns: samples[samples.len() / 2],
            min_ns: samples[0],
        }
    }
}
