//! Fig 15 — tail latency: TTFT and E2EL mean/P95/P99 for Llama3.1-8B.
//!
//! Paper (rate 0.9): PCR's tails beat LMCache's beat vLLM's across all
//! six cells — the gains are not just average-case.

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    let scale = Scale::from_env();
    section("Fig 15: TTFT and E2EL tails, llama3.1-8b @ 0.9 req/s");
    let cfg = paper_config("llama3.1-8b", "rtx4090", true, 0.9, scale);
    let wl = Workload::build(&cfg);
    let mut t = Table::new(&[
        "system", "ttft-mean", "ttft-p95", "ttft-p99",
        "e2el-mean", "e2el-p95", "e2el-p99",
    ]);
    let mut rows = Vec::new();
    for name in ["vllm", "lmcache", "pcr"] {
        let spec = SystemSpec::named(name, cfg.prefetch_window).unwrap();
        let out = engine::run(&cfg, &spec, &wl);
        t.row(&[
            name.to_string(),
            fmt_secs(out.report.ttft.mean),
            fmt_secs(out.report.ttft.p95),
            fmt_secs(out.report.ttft.p99),
            fmt_secs(out.report.e2el.mean),
            fmt_secs(out.report.e2el.p95),
            fmt_secs(out.report.e2el.p99),
        ]);
        rows.push((name, out.report));
    }
    t.print();
    let pcr = rows.iter().find(|(n, _)| *n == "pcr").unwrap().1;
    let vllm = rows.iter().find(|(n, _)| *n == "vllm").unwrap().1;
    println!(
        "\nPCR tail reductions vs vLLM: TTFT p95 -{:.0}%, e2el p99 -{:.0}% \
         (paper: >30% p99 E2EL reduction, 58 vs 103 ms TTFT p95)",
        100.0 * (1.0 - pcr.ttft.p95 / vllm.ttft.p95),
        100.0 * (1.0 - pcr.e2el.p99 / vllm.e2el.p99),
    );
    for metric in ["ttft", "e2el"] {
        let (p, v) = match metric {
            "ttft" => (pcr.ttft, vllm.ttft),
            _ => (pcr.e2el, vllm.e2el),
        };
        assert!(p.mean <= v.mean && p.p95 <= v.p95 && p.p99 <= v.p99,
                "PCR must win all six cells ({metric})");
    }
}
