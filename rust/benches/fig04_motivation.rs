//! Fig 4 — TTFT and KV-cache memory vs input length.
//!
//! Paper's points: (1) TTFT grows super-linearly with input tokens
//! (prefill is compute-bound with a quadratic attention term);
//! (2) KV bytes grow linearly but reach TB scale (0.75 TB for
//! Qwen2.5-14B and 6.23 TB for Llama2-13B at 8192k tokens), far beyond
//! CPU memory — motivating the SSD tier.

use pcr::bench::{section, Table};
use pcr::hw::gpu::GpuCostModel;
use pcr::hw::spec::{model_spec, platform_spec};
use pcr::util::fmt_bytes;

fn main() {
    section("Fig 4: TTFT and KV-cache size vs input tokens");
    let platform = platform_spec("a6000").unwrap();
    for name in ["qwen2.5-14b", "llama2-13b"] {
        let model = model_spec(name).unwrap();
        let gpu = GpuCostModel::new(&model, &platform);
        println!("\nmodel = {name}");
        let mut t = Table::new(&["tokens", "ttft", "ttft/token(us)", "kv-bytes"]);
        let mut prev_per_tok = 0.0;
        for tokens in [1024u64, 2048, 4096, 8192, 16384, 32768, 65536] {
            let ttft = gpu.prefill_time(0, tokens);
            let per_tok = ttft / tokens as f64 * 1e6;
            t.row(&[
                tokens.to_string(),
                format!("{ttft:.3} s"),
                format!("{per_tok:.1}"),
                fmt_bytes(model.kv_bytes_per_token() * tokens),
            ]);
            // super-linearity: per-token cost must keep rising
            assert!(per_tok > prev_per_tok, "TTFT must be super-linear");
            prev_per_tok = per_tok;
        }
        t.print();
        // the paper's TB-scale observation at 8192k tokens
        let huge = model.kv_bytes_per_token() * 8_192_000;
        println!(
            "at 8192k tokens: KV = {:.2} TB (paper: {})",
            huge as f64 / 1e12,
            if name == "llama2-13b" { "6.23 TB" } else { "0.75 TB*" },
        );
    }
    println!("\n(* the paper's Qwen point assumes a smaller per-token KV than the\n   published 48-layer/8-kv-head geometry; the *shape* — linear growth to\n   TB scale, far beyond CPU memory — is the reproduction target.)");
}
