//! Fig 10 — retrieval latency vs generation latency.
//!
//! Retrieval is *measured for real* (our HNSW index over the synthetic
//! corpus); generation comes from the calibrated engine run. The
//! paper's point: retrieval is orders of magnitude faster, so queued
//! requests have already retrieved their documents — the window the
//! prefetcher exploits.

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    section("Fig 10: retrieval vs generation latency across request rates");
    let scale = Scale::from_env();
    for model in ["llama3.1-8b", "llama2-13b"] {
        println!("\nmodel = {model}");
        let mut t = Table::new(&[
            "rate", "retrieval-mean", "retrieval-p99", "generation-mean", "ratio",
        ]);
        for rate in [0.5, 0.75, 1.0] {
            let cfg = paper_config(model, "a6000", true, rate, scale);
            let wl = Workload::build(&cfg);
            let spec = SystemSpec::named("pcr", cfg.prefetch_window).unwrap();
            let out = engine::run(&cfg, &spec, &wl);
            // retrieval wall times were measured while building the
            // dataset (real HNSW searches)
            let mut retr = pcr::util::stats::Samples::new();
            for item in &wl.items {
                retr.push(item.retrieval_seconds);
            }
            let gen_mean = out.report.compute_time.mean + out.report.e2el.mean
                - out.report.ttft.mean; // prefill + decode portion
            let ratio = gen_mean / retr.mean().max(1e-9);
            t.row(&[
                format!("{rate:.2}"),
                fmt_secs(retr.mean()),
                fmt_secs(retr.percentile(99.0)),
                fmt_secs(gen_mean),
                format!("{ratio:.0}x"),
            ]);
            assert!(
                retr.mean() * 10.0 < gen_mean,
                "retrieval must be far cheaper than generation"
            );
        }
        t.print();
    }
    println!("\nretrieval << generation at every rate: queued requests have their\ndocuments long before the executor reaches them (the prefetch window).");
}
