//! Fig 18 — (left) layer-wise overlap breakdown: only-up / only-down /
//! up-down; (right) prefetch window-size sweep.
//!
//! Paper's shapes: offload overlap (only-down) is worth more than load
//! overlap (only-up) because ALL new KV writes back while only the
//! matched fraction loads; for tiny-KV Qwen the stream-sync overhead
//! can make only-down beat up-down; window 6 ≈ optimal for
//! Llama2-7B-class KV, with bigger gains at the high rate.

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::{section, Table};
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::sim::pipeline::OverlapMode;
use pcr::util::fmt_secs;

fn main() {
    let scale = Scale::from_env();
    section("Fig 18 (left): overlap-mode breakdown (TTFT reduction vs sync)");
    let mut t = Table::new(&[
        "model", "sync", "only-up", "only-down", "up-down", "down-gain%", "up-gain%",
    ]);
    for model in ["qwen2.5-7b", "qwen2.5-14b", "llama2-7b", "llama2-13b"] {
        let cfg = paper_config(model, "a6000", true, 0.75, scale);
        let wl = Workload::build(&cfg);
        let run = |mode: OverlapMode| {
            let mut spec = SystemSpec::pcr_with_overlap(mode);
            spec.prefetch_window = cfg.prefetch_window;
            engine::run(&cfg, &spec, &wl).report.ttft.mean
        };
        let sync = run(OverlapMode::Sync);
        let up = run(OverlapMode::OnlyUp);
        let down = run(OverlapMode::OnlyDown);
        let updown = run(OverlapMode::UpDown);
        let down_gain = 100.0 * (1.0 - down / sync);
        let up_gain = 100.0 * (1.0 - up / sync);
        t.row(&[
            model.to_string(),
            fmt_secs(sync),
            fmt_secs(up),
            fmt_secs(down),
            fmt_secs(updown),
            format!("{down_gain:.1}"),
            format!("{up_gain:.1}"),
        ]);
        assert!(
            down_gain >= up_gain - 0.5,
            "{model}: offload overlap must dominate (all new KV written, \
             only matched KV loaded)"
        );
    }
    t.print();

    section("Fig 18 (right): prefetch window-size sweep, llama2-7b");
    let mut t = Table::new(&["window", "ttft@0.5", "ttft@1.0", "red-vs-w0@1.0"]);
    let mut base_high = 0.0;
    for window in [0usize, 2, 4, 6, 8] {
        let mut row = vec![window.to_string()];
        let mut red = String::new();
        for rate in [0.5, 1.0] {
            let cfg = paper_config("llama2-7b", "a6000", true, rate, scale);
            let wl = Workload::build(&cfg);
            let spec = SystemSpec::named("pcr", window).unwrap();
            let ttft = engine::run(&cfg, &spec, &wl).report.ttft.mean;
            row.push(fmt_secs(ttft));
            if rate == 1.0 {
                if window == 0 {
                    base_high = ttft;
                }
                red = format!("-{:.1}%", 100.0 * (1.0 - ttft / base_high));
            }
        }
        row.push(red);
        t.row(&row);
    }
    t.print();
    println!("\nwindow gains are larger at the high rate (deeper queue = more\nlook-ahead), matching the paper's -31% TTFT moving window 4 -> 6 at\nhigh rate. Optimal window is model/KV-size dependent: profile per model.");
}
