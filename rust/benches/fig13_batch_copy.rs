//! Fig 13 — chunk KV copy: block-by-block vs cudaMemcpyBatchAsync.
//!
//! Paper measures one layer of a 256-token Llama2-13B chunk scattered
//! into 16-token vLLM blocks over 32 GB/s PCIe: 0.671 ms block-by-block
//! vs 0.261 ms batched (~2.6x). We reproduce the model-level numbers
//! and also *measure* the analogous effect on this machine: scattered
//! small memcpys vs one bulk memcpy of the same bytes.

use pcr::bench::{black_box, section, Bench, Table};
use pcr::hw::spec::model_spec;
use pcr::hw::transfer::{chunk_copy_time, Channel, CopyMode};

fn main() {
    section("Fig 13: chunk copy — block-by-block vs BatchAsync (cost model)");
    let model = model_spec("llama2-13b").unwrap();
    // the paper's jetty: per-call driver cost on a 32 GB/s link
    let ch = Channel::new("pcie-32", 32.0, 12e-6);
    let mut t = Table::new(&["chunk-tokens", "block-by-block", "batch-async", "speedup"]);
    for chunk in [64u64, 128, 256, 512, 1024] {
        let slow = chunk_copy_time(&ch, &model, chunk, 16, CopyMode::BlockByBlock);
        let fast = chunk_copy_time(&ch, &model, chunk, 16, CopyMode::BatchAsync);
        t.row(&[
            chunk.to_string(),
            format!("{:.3} ms", slow * 1e3),
            format!("{:.3} ms", fast * 1e3),
            format!("{:.2}x", slow / fast),
        ]);
    }
    t.print();
    let slow = chunk_copy_time(&ch, &model, 256, 16, CopyMode::BlockByBlock);
    let fast = chunk_copy_time(&ch, &model, 256, 16, CopyMode::BatchAsync);
    println!(
        "\n256-token chunk, one layer: {:.3} ms vs {:.3} ms (paper: 0.671 vs 0.261 ms)",
        slow * 1e3,
        fast * 1e3
    );

    section("Fig 13 (measured): scattered vs bulk memcpy on this host");
    // One layer of a 256-token Llama2-13B chunk = 2*40heads*128dim*2B*256
    let layer_bytes = model.kv_bytes_per_layer(256) as usize;
    let blocks = 2 * (256 / 16); // K and V per 16-token block
    let block_bytes = layer_bytes / blocks;
    let src = vec![7u8; layer_bytes];
    let mut dst = vec![0u8; layer_bytes];

    let bulk = Bench::new("bulk copy (1 call)").min_time(0.3).run(|| {
        dst.copy_from_slice(black_box(&src));
        black_box(dst[0])
    });
    let mut dst2 = vec![0u8; layer_bytes];
    let scattered = Bench::new(format!("scattered copy ({blocks} calls)"))
        .min_time(0.3)
        .run(|| {
            for b in 0..blocks {
                let off = b * block_bytes;
                dst2[off..off + block_bytes]
                    .copy_from_slice(black_box(&src[off..off + block_bytes]));
            }
            black_box(dst2[0])
        });
    println!("{}", bulk.line());
    println!("{}", scattered.line());
    println!(
        "host-memcpy batching effect: {:.2}x (per-call overhead amortized; the\nGPU case adds ~4µs launch latency per call, hence the paper's larger gap)",
        scattered.mean_ns / bulk.mean_ns
    );
}
