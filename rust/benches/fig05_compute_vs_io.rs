//! Fig 5 — latency of computation vs IO for Qwen2.5-14B and Llama2-13B.
//!
//! Paper's crossovers: CPU-load < compute always (reuse from DRAM beats
//! recompute); SSD-load < compute in most cases (SSD is a viable
//! fallback) but by a much smaller margin; offload (D2H write) stays
//! below compute at equal token counts; SSD *write* is the slowest.

use pcr::bench::{section, Table};
use pcr::hw::gpu::GpuCostModel;
use pcr::hw::spec::{model_spec, platform_spec};
use pcr::hw::transfer::TransferFabric;

fn main() {
    section("Fig 5: computation vs IO latency");
    let platform = platform_spec("a6000").unwrap();
    for name in ["qwen2.5-14b", "llama2-13b"] {
        let model = model_spec(name).unwrap();
        let gpu = GpuCostModel::new(&model, &platform);
        let fabric = TransferFabric::new(&platform);
        println!("\nmodel = {name} (KV {} KiB/token)",
                 model.kv_bytes_per_token() / 1024);
        let mut t = Table::new(&[
            "tokens", "compute", "cpu-load", "ssd-load", "offload", "ssd-write",
        ]);
        for tokens in [1024u64, 2048, 4096, 8192] {
            let bytes = model.kv_bytes_per_token() * tokens;
            let compute = gpu.prefill_time(0, tokens);
            let cpu_load = fabric.h2d.copy_time(bytes);
            let ssd_load = fabric.ssd_read.copy_time(bytes);
            let offload = fabric.d2h.copy_time(bytes);
            let ssd_write = fabric.ssd_write.copy_time(bytes);
            t.row(&[
                tokens.to_string(),
                format!("{compute:.3} s"),
                format!("{cpu_load:.3} s"),
                format!("{ssd_load:.3} s"),
                format!("{offload:.3} s"),
                format!("{ssd_write:.3} s"),
            ]);
            assert!(cpu_load < compute, "CPU load must beat recompute");
            assert!(offload < compute, "offload must fit under compute");
        }
        t.print();
        // the paper's 8k example: ~2s compute vs ~0.5s transfer for
        // Llama2-13B => ~25% sync overhead
        if name == "llama2-13b" {
            let bytes = model.kv_bytes_per_token() * 8192;
            let c2 = gpu.prefill_time(0, 8192);
            let c1 = fabric.h2d.copy_time(bytes);
            println!(
                "8k tokens: compute {c2:.2} s, transfer {c1:.2} s -> sync reuse \
                 overhead ≈ {:.0}% of compute (paper: ~25%)",
                100.0 * c1 / c2
            );
        }
    }
}
