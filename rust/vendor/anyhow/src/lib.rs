//! Offline drop-in subset of the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the
//! pieces of `anyhow` the codebase actually uses are vendored here as a
//! path dependency: [`Error`], [`Result`], the [`Context`] trait, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Semantics match upstream
//! for those pieces: `{e}` prints the outermost message, `{e:#}` the
//! full context chain, `{e:?}` the message plus a "Caused by" list, and
//! any `std::error::Error` converts via `?`.

use std::fmt;

/// An error with an optional chain of lower-level causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The messages from outermost to root cause.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.source;
        }
        out
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, exactly
// like upstream anyhow — that keeps this blanket conversion coherent
// with the reflexive `From<Error> for Error`. Bounds mirror upstream.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert!(format!("{e:?}").contains("Caused by"));
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading x").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x: gone");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1, "too small: {x}");
            ensure!(x < 10);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "too small: 0");
        assert!(format!("{}", f(11).unwrap_err()).contains("x < 10"));
        assert!(f(5).is_err());
        let e = anyhow!("{}-{}", 1, 2);
        assert_eq!(format!("{e}"), "1-2");
    }
}
