"""L2 correctness: the served transformer.

Key invariant (the paper's accuracy claim): exact-prefix KV reuse is
*lossless* — prefilling tokens on top of a cached prefix KV reproduces
the full-recompute logits up to blocked-softmax reassociation (~1e-6;
different past/new bucket shapes partition the online softmax loop
differently, so bit-exactness only holds when partitions coincide).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (ModelConfig, decode_step, init_params,
                           make_decode_fn, make_prefill_fn, param_names,
                           param_shapes, prefill)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(vocab=128, d_model=64, n_layers=2, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=1)


def _tokens(rng, n):
    return jnp.asarray(rng.integers(0, CFG.vocab, size=n), jnp.int32)


def _zero_past(p):
    shape = (CFG.n_layers, CFG.n_kv_heads, p, CFG.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


class TestShapes:
    def test_param_table_consistent(self):
        assert len(param_names(CFG)) == len(param_shapes(CFG))
        assert param_names(CFG)[0] == "embed"
        assert param_shapes(CFG)[0] == (CFG.vocab, CFG.d_model)

    def test_prefill_output_shapes(self, params):
        rng = np.random.default_rng(0)
        zk, zv = _zero_past(32)
        logits, nk, nv = prefill(CFG, params, zk, zv, _tokens(rng, 32), 0, 32,
                                 block_q=16, block_k=16)
        assert logits.shape == (CFG.vocab,)
        assert nk.shape == (CFG.n_layers, CFG.n_kv_heads, 32, CFG.head_dim)
        assert nv.shape == nk.shape

    def test_kv_bytes_per_token(self):
        assert CFG.kv_bytes_per_token == 2 * 2 * 2 * 16 * 4

    def test_make_prefill_fn_example_args(self):
        fn, example = make_prefill_fn(CFG, 32, 16)
        assert len(example) == len(param_names(CFG)) + 5
        assert example[-3].shape == (16,)

    def test_make_decode_fn_example_args(self):
        fn, example = make_decode_fn(CFG, 64)
        assert len(example) == len(param_names(CFG)) + 4


class TestReuseLossless:
    def test_split_prefill_matches_full(self, params):
        """prefill(full) == prefill(rest | KV(prefix)) exactly."""
        rng = np.random.default_rng(2)
        toks = _tokens(rng, 96)
        zk, zv = _zero_past(32)
        full, nk, nv = prefill(CFG, params, zk, zv, toks, 0, 96,
                               block_q=32, block_k=32)

        lg1, k1, v1 = prefill(CFG, params, zk, zv,
                              jnp.pad(toks[:32], (0, 64)), 0, 32,
                              block_q=32, block_k=32)
        lg2, k2, v2 = prefill(CFG, params, k1[:, :, :32], v1[:, :, :32],
                              jnp.pad(toks[32:], (0, 32)), 32, 64,
                              block_q=32, block_k=32)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(lg2))
        np.testing.assert_array_equal(np.asarray(nk[:, :, 32:96]),
                                      np.asarray(k2[:, :, :64]))

    def test_three_way_split(self, params):
        rng = np.random.default_rng(3)
        toks = _tokens(rng, 96)
        zk, zv = _zero_past(64)
        full, _, _ = prefill(CFG, params, zk, zv,
                             jnp.pad(toks, (0, 0)), 0, 96,
                             block_q=32, block_k=32)
        # chunk 1
        _, k1, v1 = prefill(CFG, params, zk, zv,
                            jnp.pad(toks[:32], (0, 0)), 0, 32,
                            block_q=32, block_k=32)
        # chunk 2 on top of chunk 1
        _, k2, v2 = prefill(CFG, params,
                            jnp.pad(k1, ((0, 0), (0, 0), (0, 32), (0, 0))),
                            jnp.pad(v1, ((0, 0), (0, 0), (0, 32), (0, 0))),
                            jnp.pad(toks[32:64], (0, 0)), 32, 32,
                            block_q=32, block_k=32)
        past_k = jnp.concatenate([k1, k2], axis=2)
        past_v = jnp.concatenate([v1, v2], axis=2)
        lg3, _, _ = prefill(CFG, params, past_k, past_v,
                            toks[64:], 64, 32, block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(full), np.asarray(lg3),
                                   atol=1e-5, rtol=1e-3)

    def test_bucket_padding_does_not_leak(self, params):
        """Padded past slots / padded tokens must not change the logits."""
        rng = np.random.default_rng(4)
        toks = _tokens(rng, 32)
        zk, zv = _zero_past(32)
        base, _, _ = prefill(CFG, params, zk, zv, toks, 0, 32,
                             block_q=32, block_k=32)
        # garbage in the padded past
        gk = zk + 37.0
        gv = zv - 11.0
        alt, _, _ = prefill(CFG, params, gk, gv, toks, 0, 32,
                            block_q=32, block_k=32)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(alt))
        # extra garbage tokens beyond new_len
        toks2 = jnp.concatenate([toks, _tokens(rng, 32)])
        alt2, _, _ = prefill(CFG, params, zk, zv, toks2, 0, 32,
                             block_q=32, block_k=32)
        # different N bucket -> different online-softmax partitioning,
        # so equality holds only up to float reassociation
        np.testing.assert_allclose(np.asarray(base), np.asarray(alt2),
                                   atol=1e-5, rtol=1e-3)

    def test_pallas_matches_dense_path(self, params):
        rng = np.random.default_rng(5)
        toks = _tokens(rng, 64)
        zk, zv = _zero_past(32)
        a, ka, va = prefill(CFG, params, zk, zv, toks, 0, 64,
                            use_pallas=True, block_q=32, block_k=32)
        b, kb, vb = prefill(CFG, params, zk, zv, toks, 0, 64,
                            use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), atol=1e-5)


class TestDecode:
    def test_decode_matches_prefill_continuation(self, params):
        """Decoding token t on a prefilled cache == prefilling [..., t]."""
        rng = np.random.default_rng(6)
        toks = _tokens(rng, 33)
        s_max = 64
        zk, zv = _zero_past(0)
        # prefill first 32 via the dense path, pad cache to s_max
        _, k1, v1 = prefill(CFG, params, zk, zv, toks[:32], 0, 32,
                            use_pallas=False)
        kc = jnp.pad(k1, ((0, 0), (0, 0), (0, s_max - 32), (0, 0)))
        vc = jnp.pad(v1, ((0, 0), (0, 0), (0, s_max - 32), (0, 0)))
        lg_dec, kc2, vc2 = decode_step(CFG, params, kc, vc, toks[32], 32)

        lg_full, nk, nv = prefill(CFG, params, zk, zv, toks, 0, 33,
                                  use_pallas=False)
        np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                                   atol=1e-4, rtol=1e-4)
        # the cache slot 32 was filled with the new token's KV
        np.testing.assert_allclose(np.asarray(kc2[:, :, 32]),
                                   np.asarray(nk[:, :, 32]), atol=1e-5)

    def test_decode_cache_untouched_elsewhere(self, params):
        rng = np.random.default_rng(7)
        s_max = 64
        kc = jnp.asarray(rng.normal(size=(CFG.n_layers, CFG.n_kv_heads,
                                          s_max, CFG.head_dim)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=kc.shape), jnp.float32)
        _, kc2, vc2 = decode_step(CFG, params, kc, vc, 5, 10)
        np.testing.assert_array_equal(np.asarray(kc2[:, :, :10]),
                                      np.asarray(kc[:, :, :10]))
        np.testing.assert_array_equal(np.asarray(kc2[:, :, 11:]),
                                      np.asarray(kc[:, :, 11:]))


class TestDeterminism:
    def test_init_params_deterministic(self):
        a = init_params(CFG, seed=9)
        b = init_params(CFG, seed=9)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_prefill_deterministic(self, params):
        rng = np.random.default_rng(8)
        toks = _tokens(rng, 32)
        zk, zv = _zero_past(32)
        a, _, _ = prefill(CFG, params, zk, zv, toks, 0, 32,
                          block_q=16, block_k=16)
        b, _, _ = prefill(CFG, params, zk, zv, toks, 0, 32,
                          block_q=16, block_k=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
