"""AOT export contract tests: manifest/weights/HLO artifacts the rust
runtime depends on. Uses a tiny config + 2 buckets to stay fast."""

import json
import os

import numpy as np
import pytest

import compile.aot as aot
from compile.model import ModelConfig, init_params, param_shapes


@pytest.fixture(scope="module")
def exported(tmp_path_factory, monkeypatch_module=None):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2,
                      n_kv_heads=1, head_dim=16, d_ff=64)
    # shrink the bucket/decode tables for the test export
    orig_buckets, orig_decode = aot.PREFILL_BUCKETS, aot.DECODE_MAX_LEN
    aot.PREFILL_BUCKETS = [(32, 32), (64, 32)]
    aot.DECODE_MAX_LEN = 128
    try:
        manifest = aot.export(str(out), cfg, verbose=False)
    finally:
        aot.PREFILL_BUCKETS, aot.DECODE_MAX_LEN = orig_buckets, orig_decode
    return str(out), cfg, manifest


def test_manifest_round_trips(exported):
    out, cfg, manifest = exported
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["model"]["n_kv_heads"] == cfg.n_kv_heads
    assert on_disk["dtype"] == "f32"


def test_weights_bin_size_matches_param_table(exported):
    out, cfg, manifest = exported
    expect = sum(int(np.prod(p["shape"])) for p in manifest["params"]) * 4
    assert os.path.getsize(os.path.join(out, manifest["weights_file"])) == expect


def test_weights_bin_contents_match_init(exported):
    out, cfg, manifest = exported
    params = init_params(cfg, seed=aot.SEED)
    blob = np.fromfile(os.path.join(out, manifest["weights_file"]),
                       dtype="<f4")
    off = 0
    for p in params:
        flat = np.asarray(p).ravel()
        np.testing.assert_array_equal(blob[off:off + flat.size], flat)
        off += flat.size
    assert off == blob.size


def test_artifact_files_exist_and_are_hlo(exported):
    out, cfg, manifest = exported
    assert len(manifest["artifacts"]) == 3  # 2 prefill + 1 decode
    for art in manifest["artifacts"]:
        path = os.path.join(out, art["file"])
        assert os.path.exists(path)
        head = open(path).read(200)
        assert "HloModule" in head


def test_prefill_artifact_declares_bucket_shapes(exported):
    out, cfg, manifest = exported
    art = [a for a in manifest["artifacts"] if a["kind"] == "prefill"][1]
    text = open(os.path.join(out, art["file"])).read()
    p, n = art["past"], art["new"]
    shape = f"f32[{cfg.n_layers},{cfg.n_kv_heads},{p},{cfg.head_dim}]"
    assert shape in text
    assert f"s32[{n}]" in text


def test_decode_artifact_declares_max_len(exported):
    out, cfg, manifest = exported
    art = [a for a in manifest["artifacts"] if a["kind"] == "decode"][0]
    assert art["max_len"] == 128
    text = open(os.path.join(out, art["file"])).read()
    assert f"f32[{cfg.n_layers},{cfg.n_kv_heads},128,{cfg.head_dim}]" in text


def test_param_order_is_stable_abi(exported):
    out, cfg, manifest = exported
    names = [p["name"] for p in manifest["params"]]
    assert names[0] == "embed"
    assert names[-1] == "lm_head"
    assert names[1:10] == [
        "l0.attn_norm", "l0.wq", "l0.wk", "l0.wv", "l0.wo",
        "l0.mlp_norm", "l0.w_gate", "l0.w_up", "l0.w_down"]
    shapes = [tuple(p["shape"]) for p in manifest["params"]]
    assert shapes == param_shapes(cfg)
