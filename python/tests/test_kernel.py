"""L1 correctness: Pallas prefill-attention kernel vs the pure-jnp oracle.

hypothesis sweeps head counts, GQA group sizes, bucket shapes, tile sizes
and valid lengths; every case asserts allclose on the *valid* region
(rows beyond new_len are bucket padding with unspecified contents).
"""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline image without hypothesis: fall back below
    HAVE_HYPOTHESIS = False

from compile.kernels.prefill_attention import prefill_attention
from compile.kernels.ref import prefill_attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _check(h, h_kv, p, n, d, past_len, new_len, *, block_q=32, block_k=32,
           seed=0, atol=2e-5):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (h, n, d))
    k = _rand(rng, (h_kv, p + n, d))
    v = _rand(rng, (h_kv, p + n, d))
    got = prefill_attention(q, k, v, past_len, new_len,
                            block_q=block_q, block_k=block_k)
    want = prefill_attention_ref(q, k, v, past_len, new_len)
    np.testing.assert_allclose(
        np.asarray(got)[:, :new_len], np.asarray(want)[:, :new_len],
        atol=atol, rtol=1e-4)
    return got


class TestBasic:
    def test_no_past(self):
        _check(h=4, h_kv=2, p=0, n=32, d=16, past_len=0, new_len=32)

    def test_full_past(self):
        _check(h=4, h_kv=2, p=64, n=32, d=16, past_len=64, new_len=32)

    def test_partial_past(self):
        _check(h=4, h_kv=2, p=64, n=32, d=16, past_len=37, new_len=32)

    def test_partial_new(self):
        _check(h=4, h_kv=2, p=64, n=32, d=16, past_len=64, new_len=13)

    def test_single_new_token(self):
        _check(h=4, h_kv=2, p=64, n=32, d=16, past_len=64, new_len=1)

    def test_mha_layout(self):
        # n_kv_heads == n_heads is the Llama2-style MHA layout.
        _check(h=4, h_kv=4, p=32, n=32, d=16, past_len=32, new_len=32)

    def test_extreme_gqa(self):
        _check(h=8, h_kv=1, p=32, n=32, d=16, past_len=16, new_len=32)

    def test_zero_past_len_with_padded_past(self):
        # Fresh request run through a past-padded bucket: every past slot
        # must be masked out even though the buffer holds garbage.
        rng = np.random.default_rng(7)
        h, h_kv, p, n, d = 4, 2, 64, 32, 16
        q = _rand(rng, (h, n, d))
        k = _rand(rng, (h_kv, p + n, d))
        v = _rand(rng, (h_kv, p + n, d))
        got = prefill_attention(q, k, v, 0, n, block_q=32, block_k=32)
        # Same new KV, totally different past contents -> same output.
        k2 = k.at[:, :p].set(_rand(rng, (h_kv, p, d)) * 100.0)
        v2 = v.at[:, :p].set(_rand(rng, (h_kv, p, d)) * 100.0)
        got2 = prefill_attention(q, k2, v2, 0, n, block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(got2),
                                   atol=1e-6)

    def test_causality_within_new(self):
        # Query i must not see new key j > i: perturbing the tail tokens
        # cannot change earlier rows.
        rng = np.random.default_rng(8)
        h, h_kv, p, n, d = 2, 2, 0, 32, 16
        q = _rand(rng, (h, n, d))
        k = _rand(rng, (h_kv, n, d))
        v = _rand(rng, (h_kv, n, d))
        got = prefill_attention(q, k, v, 0, n, block_q=16, block_k=16)
        k2 = k.at[:, 16:].set(_rand(rng, (h_kv, 16, d)) * 50)
        v2 = v.at[:, 16:].set(_rand(rng, (h_kv, 16, d)) * 50)
        got2 = prefill_attention(q, k2, v2, 0, n, block_q=16, block_k=16)
        np.testing.assert_allclose(np.asarray(got)[:, :16],
                                   np.asarray(got2)[:, :16], atol=1e-6)

    def test_padding_rows_are_finite(self):
        got = _check(h=2, h_kv=2, p=32, n=32, d=8, past_len=5, new_len=3)
        assert np.all(np.isfinite(np.asarray(got)))

    def test_rejects_bad_group(self):
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError):
            prefill_attention(_rand(rng, (3, 8, 8)), _rand(rng, (2, 8, 8)),
                              _rand(rng, (2, 8, 8)), 0, 8)

    def test_rejects_short_window(self):
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError):
            prefill_attention(_rand(rng, (2, 16, 8)), _rand(rng, (2, 8, 8)),
                              _rand(rng, (2, 8, 8)), 0, 8)

    def test_rejects_misaligned_block_q(self):
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError):
            prefill_attention(_rand(rng, (2, 24, 8)), _rand(rng, (2, 24, 8)),
                              _rand(rng, (2, 24, 8)), 0, 24, block_q=16)


class TestNumerics:
    def test_softmax_scale_invariance_of_uniform_values(self):
        # If V rows are identical, output equals that row regardless of
        # the score distribution — a strong sanity check on the online
        # softmax normalization.
        rng = np.random.default_rng(3)
        h, h_kv, p, n, d = 2, 1, 32, 16, 8
        q = _rand(rng, (h, n, d)) * 3.0
        k = _rand(rng, (h_kv, p + n, d))
        row = rng.normal(size=(1, 1, d)).astype(np.float32)
        v = jnp.asarray(np.broadcast_to(row, (h_kv, p + n, d)))
        got = prefill_attention(q, k, v, p, n, block_q=16, block_k=16)
        want = np.broadcast_to(row, (h, n, d))
        np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=1e-4)

    def test_large_logits_stable(self):
        rng = np.random.default_rng(4)
        h, h_kv, p, n, d = 2, 2, 32, 16, 8
        q = _rand(rng, (h, n, d)) * 30.0
        k = _rand(rng, (h_kv, p + n, d)) * 30.0
        v = _rand(rng, (h_kv, p + n, d))
        got = prefill_attention(q, k, v, p, n, block_q=16, block_k=16)
        want = prefill_attention_ref(q, k, v, p, n)
        assert np.all(np.isfinite(np.asarray(got)))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-3)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        h_kv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        p_blocks=st.integers(0, 3),
        n_blocks=st.integers(1, 3),
        d=st.sampled_from([8, 16, 32]),
        data=st.data(),
    )
    def test_kernel_matches_ref_sweep(h_kv, group, p_blocks, n_blocks, d,
                                      data):
        """Property sweep: kernel == oracle across shapes and lengths."""
        block = 16
        p = p_blocks * block
        n = n_blocks * block
        h = h_kv * group
        past_len = data.draw(st.integers(0, p), label="past_len")
        new_len = data.draw(st.integers(1, n), label="new_len")
        block_k = data.draw(st.sampled_from([8, 16, 48]), label="block_k")
        seed = data.draw(st.integers(0, 2**16), label="seed")
        _check(h=h, h_kv=h_kv, p=p, n=n, d=d, past_len=past_len,
               new_len=new_len, block_q=block, block_k=block_k, seed=seed)

else:

    _FALLBACK_CASES = [
        # (h_kv, group, p, n, d, past_len, new_len, block_k, seed)
        (1, 1, 0, 16, 8, 0, 16, 8, 0),
        (1, 4, 16, 32, 16, 9, 32, 16, 1),
        (2, 2, 32, 16, 8, 32, 1, 48, 2),
        (2, 1, 48, 48, 32, 17, 30, 16, 3),
        (4, 2, 32, 32, 16, 0, 32, 8, 4),
        (4, 1, 16, 48, 8, 16, 48, 48, 5),
        (2, 4, 48, 16, 16, 31, 7, 16, 6),
        (1, 2, 32, 48, 32, 5, 41, 8, 7),
    ]

    @pytest.mark.parametrize(
        "h_kv,group,p,n,d,past_len,new_len,block_k,seed", _FALLBACK_CASES)
    def test_kernel_matches_ref_sweep(h_kv, group, p, n, d, past_len,
                                      new_len, block_k, seed):
        """Deterministic stand-in for the hypothesis sweep when the
        hypothesis package is unavailable: a fixed grid over the same
        shape axes (GQA group, bucket blocks, head dim, valid lengths,
        K tiling)."""
        _check(h=h_kv * group, h_kv=h_kv, p=p, n=n, d=d, past_len=past_len,
               new_len=new_len, block_q=16, block_k=block_k, seed=seed)
