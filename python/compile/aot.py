"""AOT export: lower the L2 model (with the L1 Pallas kernel inlined) to
HLO *text* artifacts the rust runtime loads via PJRT.

Run once at build time (``make artifacts``); Python is never on the
request path. Interchange is HLO text, NOT ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``artifacts/``):
  manifest.json                 model config, param table, bucket table
  weights.bin                   all parameters, f32 LE, concatenated in
                                the param-table order
  prefill_p{P}_n{N}.hlo.txt     one per (past, new) shape bucket
  decode_s{S}.hlo.txt           padded decode step

HLO parameter ABI (the rust side reconstructs this from the manifest):
  prefill: [*weights, past_k[L,Hkv,P,D], past_v, tokens[N] i32,
            past_len i32[], new_len i32[]] -> tuple(logits[V], new_k, new_v)
  decode:  [*weights, k_cache[L,Hkv,S,D], v_cache, token i32[],
            cur_len i32[]] -> tuple(logits[V], k_cache', v_cache')
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import (ModelConfig, init_params, make_decode_fn,
                           make_prefill_fn, param_names, param_shapes)

# (past, new) shape buckets. past_len=0..P and new_len=1..N are dynamic
# within a bucket; the rust runtime picks the smallest bucket that fits.
# 128 is also the cache-engine chunk size (tokens), so P covers 1..4
# reused chunks and N covers 1..4 computed chunks per step.
PREFILL_BUCKETS = [(128, 128), (128, 256), (128, 512),
                   (256, 128), (256, 256), (256, 512),
                   (512, 128), (512, 256), (512, 512)]
DECODE_MAX_LEN = 1024
CHUNK_TOKENS = 128
SEED = 20260710


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def export(out_dir: str, cfg: ModelConfig, *, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg, seed=SEED)
    names = param_names(cfg)
    shapes = param_shapes(cfg)

    # weights.bin — flat f32 little-endian in param-table order.
    weights_path = os.path.join(out_dir, "weights.bin")
    with open(weights_path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())

    artifacts = []
    for (p, n) in PREFILL_BUCKETS:
        t0 = time.time()
        fn, example = make_prefill_fn(cfg, p, n)
        text = to_hlo_text(jax.jit(fn).lower(*example))
        name = f"prefill_p{p}_n{n}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        artifacts.append({"kind": "prefill", "past": p, "new": n, "file": name})
        if verbose:
            print(f"  lowered {name} ({len(text)} chars, {time.time()-t0:.1f}s)",
                  file=sys.stderr)

    t0 = time.time()
    fn, example = make_decode_fn(cfg, DECODE_MAX_LEN)
    text = to_hlo_text(jax.jit(fn).lower(*example))
    decode_name = f"decode_s{DECODE_MAX_LEN}.hlo.txt"
    with open(os.path.join(out_dir, decode_name), "w") as f:
        f.write(text)
    artifacts.append({"kind": "decode", "max_len": DECODE_MAX_LEN,
                      "file": decode_name})
    if verbose:
        print(f"  lowered {decode_name} ({len(text)} chars, {time.time()-t0:.1f}s)",
              file=sys.stderr)

    manifest = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff, "rope_theta": cfg.rope_theta,
        },
        "dtype": "f32",
        "seed": SEED,
        "chunk_tokens": CHUNK_TOKENS,
        "params": [{"name": nm, "shape": list(sh)}
                   for nm, sh in zip(names, shapes)],
        "weights_file": "weights.bin",
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    cfg = ModelConfig()
    manifest = export(args.out_dir, cfg, verbose=not args.quiet)
    n_params = sum(int(np.prod(p["shape"])) for p in manifest["params"])
    print(f"exported {len(manifest['artifacts'])} artifacts, "
          f"{n_params} params -> {args.out_dir}")


if __name__ == "__main__":
    main()
