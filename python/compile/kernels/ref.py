"""Pure-jnp oracle for the prefill-attention Pallas kernel.

Same contract as :func:`prefill_attention` (see that module's docstring),
written as straight-line dense attention with explicit masks. pytest
compares the Pallas kernel against this across shape/dtype/length sweeps
— this file is the correctness ground truth of the whole L1 layer, keep
it boring.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def prefill_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                          past_len, new_len) -> jax.Array:
    """Dense reference attention over ``[past KV ‖ new KV]``.

    q: [H, N, D]; k, v: [Hkv, P+N, D]; returns [H, N, D].
    """
    h, n, d = q.shape
    h_kv, s_total, _ = k.shape
    p = s_total - n
    group = h // h_kv

    past_len = jnp.asarray(past_len, jnp.int32).reshape(())
    new_len = jnp.asarray(new_len, jnp.int32).reshape(())

    # Expand KV heads to match query heads (GQA share pattern).
    kk = jnp.repeat(k, group, axis=0)  # [H, S, D]
    vv = jnp.repeat(v, group, axis=0)

    scores = jnp.einsum("hnd,hsd->hns", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / math.sqrt(d)

    i = jnp.arange(n)[:, None]       # query new-token index [N, 1]
    j = jnp.arange(s_total)[None, :]  # absolute key slot     [1, S]
    jn = j - p
    visible = jnp.where(j < p, j < past_len,
                        (jn <= i) & (jn < new_len) & (jn >= 0))  # [N, S]
    scores = jnp.where(visible[None, :, :], scores, NEG_INF)

    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hns,hsd->hnd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)
