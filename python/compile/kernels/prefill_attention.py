"""L1 — Pallas prefill-attention kernel with prefix KV-cache reuse.

This is the compute hot-spot of the PCR paper: the prefill phase of a
GQA/MHA transformer where a *prefix* of the KV cache (``past_len`` tokens)
has been loaded from the cache engine and only the remaining ``new_len``
tokens are computed. The kernel consumes

  q        : [H,   N, D]   queries for the N new-token slots (post-rotary)
  k, v     : [Hkv, S, D]   keys/values for the full window, laid out as
                           ``[past-slot 0..P) ‖ new-slot 0..N)`` with
                           S = P + N (P, N are *static* bucket sizes)
  past_len : (1,) int32    number of valid past slots   (0 <= past_len <= P)
  new_len  : (1,) int32    number of valid new tokens   (1 <= new_len  <= N)

and produces ``o : [H, N, D]``. Validity masking (bucket padding) and the
causal structure are resolved *inside* the kernel:

  key j is visible to query i  iff
      j <  P :  j < past_len                      (valid past slot)
      j >= P :  (j-P) <= i  and  (j-P) < new_len  (causal over new slots)

Hardware adaptation (paper targets CUDA threadblocks/HBM/shared-mem):
on TPU the Q tiles live in VMEM via ``BlockSpec`` — grid = (heads,
q-blocks) — and the KV axis is streamed through the MXU-shaped
``(block_q, D) x (D, block_k)`` contractions with a flash-style online
softmax accumulator, which is the VMEM/MXU analogue of the paper's
threadblock staging. ``interpret=True`` everywhere in this repo: the CPU
PJRT client cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation); real-TPU perf is estimated analytically in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. block_q tiles the query axis through the grid;
# block_k is the KV streaming step of the online-softmax inner loop.
# 8x128 would be the native TPU tile; we keep multiples of 8 and let
# callers shrink for tiny test shapes.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 128

NEG_INF = -1e30


def _attention_kernel(past_len_ref, new_len_ref, q_ref, k_ref, v_ref, o_ref,
                      *, block_k: int, past_slots: int):
    """One (head, q-block) grid cell: online-softmax over KV blocks."""
    qi = pl.program_id(1)
    past_len = past_len_ref[0]
    new_len = new_len_ref[0]

    q = q_ref[0, :, :]  # [bq, D]
    block_q, d = q.shape
    s_total = k_ref.shape[1]
    scale = 1.0 / math.sqrt(d)

    # Absolute new-token indices covered by this q block.
    q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    num_kb = pl.cdiv(s_total, block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_blk = jax.lax.dynamic_slice(
            k_ref[0, :, :], (kb * block_k, 0), (block_k, d))  # [bk, D]
        v_blk = jax.lax.dynamic_slice(
            v_ref[0, :, :], (kb * block_k, 0), (block_k, d))  # [bk, D]

        # MXU contraction: [bq, D] x [D, bk] -> [bq, bk]
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

        # Visibility mask for this block of keys.
        j = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)  # [1, bk] absolute key slot
        is_past = j < past_slots
        past_ok = j < past_len
        jn = j - past_slots  # index within the new slots
        new_ok = (jn <= q_idx) & (jn < new_len) & (jn >= 0)
        mask = jnp.where(is_past, past_ok, new_ok)  # [bq, bk]
        s = jnp.where(mask, s, NEG_INF)

        # Online softmax (flash-attention recurrence).
        m_cur = jnp.max(s, axis=1, keepdims=True)  # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # masked entries underflow to ~0
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))

    # Rows beyond new_len are bucket padding: their mask may still admit
    # keys, so l > 0, but guard anyway so padding can never produce NaNs.
    l = jnp.where(l > 0.0, l, 1.0)
    o_ref[0, :, :] = (acc / l).astype(o_ref.dtype)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      past_len: jax.Array, new_len: jax.Array,
                      *, block_q: int = DEFAULT_BLOCK_Q,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jax.Array:
    """Blocked causal attention over ``[past KV ‖ new KV]``.

    Args:
      q: ``[H, N, D]`` new-token queries (rotary already applied).
      k, v: ``[Hkv, P + N, D]`` full KV window, past slots first.
      past_len: scalar or ``(1,)`` int32, valid past slots.
      new_len: scalar or ``(1,)`` int32, valid new tokens.
      block_q / block_k: tile sizes (clamped to the actual extents).
      interpret: must stay True off-TPU (Mosaic custom-calls cannot run
        on the CPU PJRT plugin).

    Returns:
      ``[H, N, D]`` attention outputs for the new-token slots.
    """
    h, n, d = q.shape
    h_kv, s_total, d_k = k.shape
    if d_k != d or v.shape != k.shape:
        raise ValueError(f"inconsistent shapes q={q.shape} k={k.shape} v={v.shape}")
    if h % h_kv != 0:
        raise ValueError(f"n_heads={h} not a multiple of n_kv_heads={h_kv}")
    past_slots = s_total - n
    if past_slots < 0:
        raise ValueError(f"KV window {s_total} shorter than new tokens {n}")
    group = h // h_kv

    block_q = min(block_q, n)
    block_k = min(block_k, s_total)
    if n % block_q != 0:
        raise ValueError(f"N={n} not a multiple of block_q={block_q}")
    if s_total % block_k != 0:
        # jax.lax.dynamic_slice CLAMPS out-of-range starts; a trailing
        # partial KV block would then re-read earlier keys under wrong
        # labels (found by the hypothesis sweep). Shrink block_k to the
        # largest divisor of S — the production buckets are powers of
        # two times 128 so this never triggers on the AOT path.
        block_k = max(d for d in range(1, block_k + 1) if s_total % d == 0)

    past_len = jnp.asarray(past_len, jnp.int32).reshape((1,))
    new_len = jnp.asarray(new_len, jnp.int32).reshape((1,))

    kernel = functools.partial(
        _attention_kernel, block_k=block_k, past_slots=past_slots)

    grid = (h, n // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda hh, qi: (0,)),            # past_len
            pl.BlockSpec((1,), lambda hh, qi: (0,)),            # new_len
            pl.BlockSpec((1, block_q, d), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, s_total, d), lambda hh, qi, g=group: (hh // g, 0, 0)),
            pl.BlockSpec((1, s_total, d), lambda hh, qi, g=group: (hh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, d), q.dtype),
        interpret=interpret,
    )(past_len, new_len, q, k, v)
