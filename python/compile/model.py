"""L2 — the generator being served: a small GQA transformer in JAX.

This is the "LLM executor" of the PCR paper, shrunk to a size the CPU
PJRT client can serve while keeping every structural property the paper's
evaluation leans on:

  * GQA (``n_kv_heads < n_heads``) — the Qwen2.5/Llama3 KV layout; set
    ``n_kv_heads == n_heads`` for the Llama2-style MHA layout. The KV
    bytes/token ratio between the two drives half the paper's contrasts.
  * position-dependent KV (rotary embeddings) — the reason PCR restricts
    itself to *exact prefix* reuse.
  * a prefill entrypoint that accepts a reused prefix KV cache
    (``past_k/past_v`` + ``past_len``) and returns the KV produced for
    the new tokens, which the rust cache engine chunks and stores.

Attention runs through the L1 Pallas kernel
(:mod:`compile.kernels.prefill_attention`), so the kernel lowers into the
same HLO module exported by :mod:`compile.aot`.

The invariant that makes KV reuse *lossless* (the paper's accuracy claim)
is tested in ``python/tests/test_model.py``::

    prefill(tokens[:m] ++ tokens[m:])  ==  prefill(tokens[m:], past=KV(tokens[:m]))

Everything is f32 and single-sequence; batching is the rust scheduler's
job (continuous batching interleaves sequences across steps).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.prefill_attention import prefill_attention
from compile.kernels.ref import prefill_attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of the served model."""
    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    rope_theta: float = 10000.0

    @property
    def kv_bytes_per_token(self) -> int:
        """f32 bytes of KV cache one token occupies across all layers."""
        return self.n_layers * 2 * self.n_kv_heads * self.head_dim * 4


# Parameter order is the ABI between aot.py and the rust runtime: the HLO
# parameter list is [*weights (this order), past_k, past_v, tokens,
# past_len, new_len]. Never reorder without regenerating artifacts.
def param_names(cfg: ModelConfig) -> List[str]:
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.attn_norm", f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo",
            f"l{l}.mlp_norm", f"l{l}.w_gate", f"l{l}.w_up", f"l{l}.w_down",
        ]
    names += ["final_norm", "lm_head"]
    return names


def param_shapes(cfg: ModelConfig) -> List[Tuple[int, ...]]:
    shapes = [(cfg.vocab, cfg.d_model)]
    qd = cfg.n_heads * cfg.head_dim
    kd = cfg.n_kv_heads * cfg.head_dim
    for _ in range(cfg.n_layers):
        shapes += [
            (cfg.d_model,), (cfg.d_model, qd), (cfg.d_model, kd),
            (cfg.d_model, kd), (qd, cfg.d_model),
            (cfg.d_model,), (cfg.d_model, cfg.d_ff), (cfg.d_model, cfg.d_ff),
            (cfg.d_ff, cfg.d_model),
        ]
    shapes += [(cfg.d_model,), (cfg.d_model, cfg.vocab)]
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Deterministic small-scale init (truncated-normal-ish, f32)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))  # norm gains
        else:
            fan_in = shape[0]
            scale = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


def _rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [H, N, D]; positions: [N] int32."""
    h, n, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [N, half]
    cos = jnp.cos(angles)[None, :, :]
    sin = jnp.sin(angles)[None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unflatten(cfg: ModelConfig, params: List[jax.Array]):
    """Split the flat param list into (embed, per-layer tuples, final, head)."""
    embed = params[0]
    layers = []
    idx = 1
    for _ in range(cfg.n_layers):
        layers.append(tuple(params[idx:idx + 9]))
        idx += 9
    final_norm, lm_head = params[idx], params[idx + 1]
    return embed, layers, final_norm, lm_head


def prefill(cfg: ModelConfig, params: List[jax.Array],
            past_k: jax.Array, past_v: jax.Array, tokens: jax.Array,
            past_len: jax.Array, new_len: jax.Array,
            *, use_pallas: bool = True,
            block_q: int = 64, block_k: int = 128):
    """Prefill ``tokens`` on top of a reused prefix KV cache.

    Args:
      past_k/past_v: ``[L, Hkv, P, D]`` prefix KV (post-rotary); only the
        first ``past_len`` slots are valid, the rest is bucket padding.
      tokens: ``[N]`` int32; only the first ``new_len`` are valid.
      past_len/new_len: int32 scalars.

    Returns:
      ``(logits, new_k, new_v)`` — ``logits: [vocab]`` for the *last
      valid* token (the first generated token's distribution, i.e. what
      TTFT waits for), and ``new_k/new_v: [L, Hkv, N, D]`` the KV of the
      new-token slots (garbage beyond ``new_len``; the cache engine only
      stores whole valid chunks).
    """
    embed, layers, final_norm, lm_head = _unflatten(cfg, params)
    n = tokens.shape[0]
    p = past_k.shape[2]
    past_len = jnp.asarray(past_len, jnp.int32).reshape(())
    new_len = jnp.asarray(new_len, jnp.int32).reshape(())
    positions = past_len + jnp.arange(n, dtype=jnp.int32)

    x = embed[tokens]  # [N, d]
    new_ks, new_vs = [], []
    for l, (a_norm, wq, wk, wv, wo, m_norm, w_gate, w_up, w_down) in enumerate(layers):
        h = _rms_norm(x, a_norm)
        q = (h @ wq).reshape(n, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2)
        k = (h @ wk).reshape(n, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
        v = (h @ wv).reshape(n, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)

        k_all = jnp.concatenate([past_k[l], k], axis=1)  # [Hkv, P+N, D]
        v_all = jnp.concatenate([past_v[l], v], axis=1)
        if use_pallas:
            attn = prefill_attention(q, k_all, v_all, past_len, new_len,
                                     block_q=min(block_q, n),
                                     block_k=min(block_k, p + n))
        else:
            attn = prefill_attention_ref(q, k_all, v_all, past_len, new_len)
        attn = attn.transpose(1, 0, 2).reshape(n, cfg.n_heads * cfg.head_dim)
        x = x + attn @ wo

        h2 = _rms_norm(x, m_norm)
        x = x + (jax.nn.silu(h2 @ w_gate) * (h2 @ w_up)) @ w_down
        new_ks.append(k)
        new_vs.append(v)

    x = _rms_norm(x, final_norm)
    last = jnp.clip(new_len - 1, 0, n - 1)
    logits = x[last] @ lm_head  # [vocab]
    new_k = jnp.stack(new_ks)  # [L, Hkv, N, D]
    new_v = jnp.stack(new_vs)
    return logits, new_k, new_v


def decode_step(cfg: ModelConfig, params: List[jax.Array],
                k_cache: jax.Array, v_cache: jax.Array,
                token: jax.Array, cur_len: jax.Array):
    """One decode step against a padded KV cache.

    k_cache/v_cache: ``[L, Hkv, S_max, D]``; ``cur_len`` valid entries.
    Returns ``(logits, k_cache', v_cache')`` with the new token's KV
    written at slot ``cur_len``. Decode is memory-bound, not the paper's
    hot-spot, so it uses the dense reference attention.
    """
    embed, layers, final_norm, lm_head = _unflatten(cfg, params)
    cur_len = jnp.asarray(cur_len, jnp.int32).reshape(())
    positions = cur_len[None]

    x = embed[jnp.asarray(token, jnp.int32).reshape((1,))]  # [1, d]
    k_out, v_out = [], []
    for l, (a_norm, wq, wk, wv, wo, m_norm, w_gate, w_up, w_down) in enumerate(layers):
        h = _rms_norm(x, a_norm)
        q = (h @ wq).reshape(1, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2)
        k = (h @ wk).reshape(1, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
        v = (h @ wv).reshape(1, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)

        kc = jax.lax.dynamic_update_slice(k_cache[l], k, (0, cur_len, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[l], v, (0, cur_len, 0))
        k_out.append(kc)
        v_out.append(vc)

        # Single query attending over cur_len+1 valid slots: past window
        # is the padded cache, new window is this one token.
        attn = prefill_attention_ref(
            q, jnp.concatenate([kc, k], axis=1),
            jnp.concatenate([vc, v], axis=1),
            cur_len, jnp.int32(1))
        attn = attn.transpose(1, 0, 2).reshape(1, cfg.n_heads * cfg.head_dim)
        x = x + attn @ wo
        h2 = _rms_norm(x, m_norm)
        x = x + (jax.nn.silu(h2 @ w_gate) * (h2 @ w_up)) @ w_down

    x = _rms_norm(x, final_norm)
    logits = x[0] @ lm_head
    return logits, jnp.stack(k_out), jnp.stack(v_out)


def make_prefill_fn(cfg: ModelConfig, p: int, n: int, *, use_pallas: bool = True):
    """Close over the config for a fixed (past=P, new=N) shape bucket."""
    n_params = len(param_names(cfg))

    def fn(*args):
        params = list(args[:n_params])
        past_k, past_v, tokens, past_len, new_len = args[n_params:]
        return prefill(cfg, params, past_k, past_v, tokens, past_len, new_len,
                       use_pallas=use_pallas)

    example = tuple(
        jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes(cfg)
    ) + (
        jax.ShapeDtypeStruct((cfg.n_layers, cfg.n_kv_heads, p, cfg.head_dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_layers, cfg.n_kv_heads, p, cfg.head_dim), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, example


def make_decode_fn(cfg: ModelConfig, s_max: int):
    """Close over the config for the padded decode bucket."""
    n_params = len(param_names(cfg))

    def fn(*args):
        params = list(args[:n_params])
        k_cache, v_cache, token, cur_len = args[n_params:]
        return decode_step(cfg, params, k_cache, v_cache, token, cur_len)

    example = tuple(
        jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes(cfg)
    ) + (
        jax.ShapeDtypeStruct((cfg.n_layers, cfg.n_kv_heads, s_max, cfg.head_dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_layers, cfg.n_kv_heads, s_max, cfg.head_dim), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, example
