//! Cluster tour: scale one PCR engine to a replica fleet and watch
//! what routing does to the fleet's cache — why spraying repeats
//! across replicas destroys the hit ratio, and how the global prefix
//! directory gets it back.
//!
//!     cargo run --release --example cluster_tour

use pcr::bench::Table;
use pcr::cluster::router::registry as routers;
use pcr::cluster::sim::run_with;
use pcr::config::ExperimentConfig;
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    let cfg = ExperimentConfig {
        model: "llama2-7b".into(),
        platform: "a6000".into(),
        system: "pcr".into(),
        n_inputs: 120,
        n_requests: 360,
        oversample: true,
        rate: 1.0,
        n_docs: 500,
        n_topics: 24,
        mean_doc_tokens: 600,
        query_tokens: 48,
        chunk_tokens: 256,
        gpu_bytes: 2 * (1 << 30),
        dram_bytes: 6 * (1 << 30),
        ssd_bytes: 40 * (1 << 30),
        ..Default::default()
    };
    cfg.validate().expect("tour config");
    let wl = Workload::build(&cfg);
    let spec = SystemSpec::try_named("pcr", cfg.prefetch_window).expect("registered system");
    println!(
        "fixed workload: llama2-7b @ 1.0 req/s, {} requests over {} inputs, {:.0}% repetition\n",
        wl.len(),
        wl.n_distinct_inputs,
        wl.repetition_ratio * 100.0
    );

    println!("1) one replica is just the single-engine simulator");
    let single = engine::run(&cfg, &spec, &wl);
    let one = run_with(&cfg, &spec, &wl, 1, routers::parse("round-robin").unwrap());
    println!(
        "   engine::run  ttft {}   cluster(replicas=1)  ttft {}   (identical by construction)",
        fmt_secs(single.report.ttft.mean),
        fmt_secs(one.aggregate.ttft.mean)
    );

    println!("\n2) four replicas — every routing policy on the same workload");
    let mut t = Table::new(&["router", "ttft-mean", "ttft-p99", "hit%", "imbalance", "stale"]);
    for name in routers::NAMES {
        let out = run_with(&cfg, &spec, &wl, 4, routers::parse(name).unwrap());
        t.row(&[
            name.to_string(),
            fmt_secs(out.aggregate.ttft.mean),
            fmt_secs(out.aggregate.ttft.p99),
            format!("{:.1}", out.hit_ratio * 100.0),
            format!("{:.3}", out.load_imbalance),
            out.directory_stale.to_string(),
        ]);
    }
    t.print();
    println!(
        "   round-robin rebuilds each hot prefix on every replica it lands on;\n\
         \x20  the directory-driven routers send repeats to the replica already holding them."
    );

    println!("\n3) scaling the fleet under affinity-balanced routing");
    let mut t = Table::new(&["replicas", "ttft-mean", "hit%", "directory-chunks"]);
    for n in [1usize, 2, 4, 8] {
        let out = run_with(&cfg, &spec, &wl, n, routers::parse("affinity-balanced").unwrap());
        t.row(&[
            n.to_string(),
            fmt_secs(out.aggregate.ttft.mean),
            format!("{:.1}", out.hit_ratio * 100.0),
            out.directory_entries.to_string(),
        ]);
    }
    t.print();

    println!(
        "\nthe directory never walks a replica's prefix tree: it mirrors residency\n\
         events (one u64 holder mask per chunk), so routing stays O(chain depth)\n\
         no matter how big each replica's cache grows. try it from the CLI:\n\
         \x20   pcr cluster --replicas 4 --router affinity-balanced:0.25"
    );
}
