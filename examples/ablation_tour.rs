//! Ablation tour: walk every design decision of PCR and show what it
//! buys, on one fixed workload — a guided version of the paper's §6.3
//! and §6.4 for people reading the code.
//!
//!     cargo run --release --example ablation_tour

use pcr::bench::scenario::{paper_config, Scale};
use pcr::bench::Table;
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::sim::pipeline::OverlapMode;
use pcr::util::fmt_secs;

fn main() {
    let cfg = paper_config("llama2-7b", "a6000", true, 0.9, Scale::Lite);
    let wl = Workload::build(&cfg);
    println!(
        "fixed workload: llama2-7b @ 0.9 req/s, {} requests, {:.0}% repetition\n",
        wl.len(),
        wl.repetition_ratio * 100.0
    );
    let run = |spec: SystemSpec| engine::run(&cfg, &spec, &wl);

    println!("1) storage tiers — why GPU memory alone is not enough");
    let mut t = Table::new(&["tiers", "ttft-mean", "hit%", "reuse%"]);
    for name in ["vllm", "ccache", "sccache"] {
        let out = run(SystemSpec::named(name, 0).unwrap());
        t.row(&[
            match name {
                "vllm" => "GPU only".to_string(),
                "ccache" => "GPU+DRAM".to_string(),
                _ => "GPU+DRAM+SSD".to_string(),
            },
            fmt_secs(out.report.ttft.mean),
            format!("{:.1}", out.cache.hit_ratio() * 100.0),
            format!("{:.1}", out.report.mean_reuse_ratio * 100.0),
        ]);
    }
    t.print();

    println!("\n2) layer-wise overlapping — hiding the PCIe traffic (§4.3)");
    let mut t = Table::new(&["overlap", "ttft-mean", "vs sync"]);
    let sync = run(SystemSpec::pcr_with_overlap(OverlapMode::Sync))
        .report
        .ttft
        .mean;
    for mode in [
        OverlapMode::Sync,
        OverlapMode::OnlyUp,
        OverlapMode::OnlyDown,
        OverlapMode::UpDown,
    ] {
        let out = run(SystemSpec::pcr_with_overlap(mode));
        t.row(&[
            format!("{mode:?}"),
            fmt_secs(out.report.ttft.mean),
            format!("-{:.1}%", 100.0 * (1.0 - out.report.ttft.mean / sync)),
        ]);
    }
    t.print();

    println!("\n3) queue-based prefetch — hiding the SSD (§4.4)");
    let mut t = Table::new(&["window", "ttft-mean", "prefetches", "ssd-wait(total)"]);
    for window in [0usize, 2, 4, 6] {
        let out = run(SystemSpec::named("pcr", window).unwrap());
        t.row(&[
            window.to_string(),
            fmt_secs(out.report.ttft.mean),
            out.prefetch_completed.to_string(),
            fmt_secs(out.breakdown.ssd_wait),
        ]);
    }
    t.print();

    println!("\n4) eviction policy — every registered policy on the PCR backbone (§4.2)");
    let mut t = Table::new(&["policy", "ttft-mean", "hit%"]);
    for (label, policy) in [
        ("plain LRU", "lru"),
        ("FIFO", "fifo"),
        ("PGDSF (RAGCache)", "pgdsf"),
        ("SLRU", "slru"),
        ("2Q", "2q"),
        ("LFUDA", "lfuda"),
        ("look-ahead LRU", "lookahead-lru"),
        ("look-ahead SLRU", "lookahead-slru"),
    ] {
        let spec = SystemSpec::named("pcr", 4).unwrap().with_overrides(policy, "");
        let out = run(spec);
        t.row(&[
            label.to_string(),
            fmt_secs(out.report.ttft.mean),
            format!("{:.1}", out.cache.hit_ratio() * 100.0),
        ]);
    }
    t.print();

    println!("\n5) prefetch strategy — what the queue watcher pulls off SSD (§4.4)");
    let mut t = Table::new(&["strategy", "ttft-mean", "prefetches", "ssd-wait(total)"]);
    for strategy in ["none", "queue-window", "depth-bounded:2", "depth-bounded:8"] {
        let spec = SystemSpec::named("pcr", 4).unwrap().with_overrides("", strategy);
        let out = run(spec);
        t.row(&[
            strategy.to_string(),
            fmt_secs(out.report.ttft.mean),
            out.prefetch_completed.to_string(),
            fmt_secs(out.breakdown.ssd_wait),
        ]);
    }
    t.print();

    println!("\n6) batched chunk copies — cudaMemcpyBatchAsync (Fig 13)");
    let mut t = Table::new(&["copies", "ttft-mean"]);
    for (label, batch) in [("block-by-block", false), ("batch-async", true)] {
        let mut spec = SystemSpec::named("pcr", 4).unwrap();
        spec.batch_async = batch;
        let out = run(spec);
        t.row(&[label.to_string(), fmt_secs(out.report.ttft.mean)]);
    }
    t.print();

    println!("\nfull PCR = tiers + up-down overlap + prefetch + look-ahead LRU + batched copies");
}
