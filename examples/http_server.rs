//! Serve the real model over HTTP and fire a small closed-loop load at
//! it from client threads — the deployable face of the system.
//!
//!     make artifacts && cargo run --release --example http_server

use pcr::rag::corpus::{Corpus, CorpusConfig};
use pcr::rag::retriever::Retriever;
use pcr::rag::tokenizer::Tokenizer;
use pcr::runtime::executor::{ExecutorHandle, PjrtExecutor};
use pcr::runtime::manifest::{default_artifacts_dir, Manifest};
use pcr::serve::server::{http_request, http_request_text, HttpServer, ServerState};
use pcr::util::json::Json;
use pcr::util::stats::Samples;
use std::sync::atomic::Ordering;
use std::sync::Mutex;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(default_artifacts_dir())?;
    let vocab = manifest.vocab as u32;
    let spill = std::env::temp_dir().join("pcr-http-example-spill");
    let executor = ExecutorHandle::spawn(move || {
        PjrtExecutor::new(manifest, 24, 256, Some(&spill), "lookahead-lru")
    })?;

    let corpus = Corpus::generate(CorpusConfig {
        n_docs: 300,
        n_topics: 16,
        vocab,
        mean_doc_tokens: 330,
        doc_tokens_jitter: 0.15,
        seed: 5,
    });
    let retriever = Retriever::build(corpus, 2);

    let state = ServerState {
        executor,
        retriever: Some(retriever),
        tokenizer: Tokenizer::new(vocab),
        ttft: Mutex::new(Samples::new()),
        requests: Mutex::new(0),
    };
    let server = HttpServer::bind("127.0.0.1:0", state)?;
    let addr = server.local_addr()?.to_string();
    let stop = server.stop_handle();
    println!("serving on http://{addr}");
    let handle = std::thread::spawn(move || server.serve(4));

    // --- closed-loop clients replaying a handful of hot queries ---
    let queries = [
        "how does the prefix tree cache kv chunks",
        "what is layer wise overlapping in pcr",
        "queue based prefetching from ssd to dram",
        "how does the prefix tree cache kv chunks", // repeat: reuse!
        "what is layer wise overlapping in pcr",
    ];
    let mut client_threads = Vec::new();
    for (c, chunk) in queries.chunks(2).enumerate() {
        let addr = addr.clone();
        let mine: Vec<String> = chunk.iter().map(|s| s.to_string()).collect();
        client_threads.push(std::thread::spawn(move || -> anyhow::Result<Vec<Json>> {
            let mut out = Vec::new();
            for q in mine {
                let body = Json::from_pairs(vec![("query", q.as_str().into())]).dump();
                let (code, j) = http_request(&addr, "POST", "/rag", &body)?;
                anyhow::ensure!(code == 200, "client {c}: {j}");
                out.push(j);
            }
            Ok(out)
        }));
    }
    let mut total_reused = 0usize;
    for t in client_threads {
        for j in t.join().unwrap()? {
            println!(
                "  first_token={} prefill={:.3}s reused={} docs={}",
                j.get("first_token").unwrap(),
                j.get("prefill_s").unwrap().as_f64().unwrap(),
                j.get("reused_tokens").unwrap(),
                j.get("doc_ids").unwrap()
            );
            total_reused += j.get("reused_tokens").unwrap().as_usize().unwrap();
        }
    }

    let (_, stats) = http_request(&addr, "GET", "/stats", "")?;
    println!("\n/stats: {stats}");
    println!("total reused tokens across clients: {total_reused}");

    // Prometheus scrape: the same counters in text exposition format,
    // ready for a scrape config pointed at this port.
    let (code, scrape) = http_request_text(&addr, "GET", "/metrics", "")?;
    anyhow::ensure!(code == 200, "metrics scrape failed: {code}");
    for series in [
        "pcr_requests_total",
        "pcr_ttft_seconds_mean",
        "pcr_cache_hit_ratio",
        "pcr_degrade_store_errors_total",
    ] {
        anyhow::ensure!(scrape.contains(series), "scrape missing {series}:\n{scrape}");
    }
    println!("\n/metrics:\n{}", scrape.trim_end());

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap()?;
    println!("server stopped cleanly");
    Ok(())
}
