//! Quickstart: the whole PCR pipeline in ~60 lines.
//!
//! Builds a synthetic RAG workload, runs the PCR serving simulator and
//! the vLLM baseline on it, and prints the TTFT comparison — the
//! 30-second version of the paper's headline experiment.
//!
//!     cargo run --release --example quickstart

use pcr::bench::Table;
use pcr::config::ExperimentConfig;
use pcr::serve::engine;
use pcr::serve::system::SystemSpec;
use pcr::serve::workload::Workload;
use pcr::util::fmt_secs;

fn main() {
    // 1. Configure an experiment (see config/ for the full knob list).
    let cfg = ExperimentConfig {
        model: "llama3.1-8b".into(),
        platform: "a6000".into(),
        rate: 0.8,           // Poisson arrivals, req/s
        n_inputs: 200,       // distinct RAG inputs in the dataset
        n_requests: 400,     // requests sampled from them (w/ repeats)
        n_docs: 1000,
        mean_doc_tokens: 1650, // 2 docs + query ≈ 3.4k tokens per input
        // Tier pressure: GPU holds a few requests' KV, DRAM a fraction
        // of the working set, SSD everything (the paper's regime).
        gpu_bytes: 4 << 30,
        dram_bytes: 16 << 30,
        ssd_bytes: 200 << 30,
        ..Default::default()
    };
    cfg.validate().expect("config");

    // 2. Build the workload: corpus -> HNSW retrieval -> dataset ->
    //    Poisson request stream. Deterministic from cfg.seed.
    let wl = Workload::build(&cfg);
    println!(
        "workload: {} requests / {} inputs, mean {:.0} tokens, {:.0}% repetition\n",
        wl.len(),
        wl.n_distinct_inputs,
        wl.mean_input_tokens,
        wl.repetition_ratio * 100.0
    );

    // 3. Serve the same stream under each system variant.
    let mut table = Table::new(&["system", "ttft-mean", "ttft-p99", "hit%", "prefetches"]);
    for name in ["vllm", "sccache", "pcr"] {
        let spec = SystemSpec::named(name, cfg.prefetch_window).unwrap();
        let out = engine::run(&cfg, &spec, &wl);
        table.row(&[
            name.to_string(),
            fmt_secs(out.report.ttft.mean),
            fmt_secs(out.report.ttft.p99),
            format!("{:.1}", out.cache.hit_ratio() * 100.0),
            out.prefetch_completed.to_string(),
        ]);
    }
    table.print();
    println!("\nPCR = prefix-tree cache + look-ahead LRU + layer-wise overlap +\nqueue-based SSD prefetch. Next: examples/e2e_serving.rs runs the real\nPJRT model instead of the cost-model simulator.");
}
