//! End-to-end driver — the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled transformer (L2 JAX model with the L1 Pallas
//! prefill-attention kernel inlined) through the PJRT CPU client, then
//! serves a batched RAG workload through the L3 coordinator's real
//! path: HNSW retrieval -> prefix-tree matching -> KV chunk reuse from
//! a DRAM tier + an on-disk SSD tier -> multi-pass prefill -> decode.
//!
//! Reports: TTFT/throughput, per-tier reuse, dual-lane transfer-engine
//! counters (prefetch-lane reads completing while compute proceeds,
//! demand upgrades of in-flight prefetches), and the paper's
//! correctness claim verified end-to-end (reused-prefix logits ==
//! cold-recompute logits).
//!
//!     make artifacts && cargo run --release --example e2e_serving

use pcr::cache::chunk::ChunkedSeq;
use pcr::cache::tier::Tier;
use pcr::io::IoConfig;
use pcr::rag::corpus::{Corpus, CorpusConfig};
use pcr::rag::retriever::Retriever;
use pcr::runtime::executor::PjrtExecutor;
use pcr::runtime::manifest::{default_artifacts_dir, Manifest};
use pcr::util::rng::Rng;
use pcr::util::stats::Samples;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(default_artifacts_dir())?;
    println!(
        "model: {} layers, {} heads ({} kv), d={}, vocab={}, chunk={} tokens",
        manifest.n_layers, manifest.n_heads, manifest.n_kv_heads,
        manifest.d_model, manifest.vocab, manifest.chunk_tokens
    );
    let vocab = manifest.vocab as u32;
    let _chunk = manifest.chunk_tokens;
    let (max_p, max_n) = manifest.max_bucket();

    // Real tiers: small DRAM (12 chunks) + on-disk SSD tier, so both
    // reuse paths and evictions actually happen. SSD bytes move through
    // the dual-lane transfer engine (2 workers; deep prefetch queue).
    let spill = std::env::temp_dir().join("pcr-e2e-spill");
    let _ = std::fs::remove_dir_all(&spill); // deterministic cold start
    let t0 = Instant::now();
    let mut exec = PjrtExecutor::with_io(
        manifest,
        12,
        256,
        Some(&spill),
        "lookahead-lru",
        IoConfig { workers: 2, demand_depth: 64, prefetch_depth: 256, ..IoConfig::default() },
    )?;
    // Deployment mode: keep spill files on shutdown so a restarted
    // process reconciles (checksum-verifies and adopts) them instead of
    // re-spilling from cold.
    exec.set_spill_persist(true);
    println!("PJRT CPU client up, weights resident ({:.1}s)\n", t0.elapsed().as_secs_f64());

    // RAG frontend sized to the model's real context (P+N = 1024).
    let corpus = Corpus::generate(CorpusConfig {
        n_docs: 400,
        n_topics: 24,
        vocab,
        mean_doc_tokens: 330, // 2 docs + 64-token query ≈ 724 tokens
        doc_tokens_jitter: 0.15,
        seed: 42,
    });
    let retriever = Retriever::build(corpus, 2);

    // --- correctness first: reuse must be lossless through PJRT ---
    let mut rng = Rng::new(7);
    let q = retriever.sample_query(&mut rng, 64);
    let input = retriever.retrieve(&q);
    let cold = exec.serve(&input.tokens)?;
    let warm = exec.serve(&input.tokens)?;
    let max_diff = cold
        .logits
        .iter()
        .zip(&warm.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("losslessness check: cold vs reused-prefix logits");
    println!(
        "  reused {} of {} tokens, max |Δlogit| = {max_diff:.2e}, first token {} == {}",
        warm.reused_tokens,
        input.tokens.len(),
        cold.first_token,
        warm.first_token
    );
    anyhow::ensure!(max_diff < 1e-3, "KV reuse changed the logits!");
    anyhow::ensure!(cold.first_token == warm.first_token);

    // --- batched workload: 60 requests over 25 distinct queries ---
    let n_distinct = 25;
    let n_requests = 60;
    let queries: Vec<Vec<u32>> = (0..n_distinct)
        .map(|_| retriever.sample_query(&mut rng, 64))
        .collect();
    let mut ttft = Samples::new();
    let mut reused_tokens = 0usize;
    let mut total_tokens = 0usize;
    let (mut from_dram, mut from_ssd) = (0usize, 0usize);
    let bench_start = Instant::now();
    for i in 0..n_requests {
        let q = &queries[(i * 7 + i * i) % n_distinct]; // skewed replay
        let input = retriever.retrieve(q);
        anyhow::ensure!(input.tokens.len() <= max_p + max_n);
        // Look-ahead: warm the next request's SSD-resident chunks on
        // the prefetch lane; the reads overlap this request's prefill
        // and land in DRAM at a later serve's drain.
        let j = i + 1;
        if j < n_requests {
            let next = retriever.retrieve(&queries[(j * 7 + j * j) % n_distinct]);
            exec.prefetch_chain(&ChunkedSeq::new(&next.tokens, exec.chunk_tokens));
        }
        let r = exec.serve(&input.tokens)?;
        ttft.push(r.prefill_seconds + input.search_seconds);
        reused_tokens += r.reused_tokens;
        total_tokens += input.tokens.len();
        from_dram += r.reused_from_dram;
        from_ssd += r.reused_from_ssd;
    }
    let wall = bench_start.elapsed().as_secs_f64();

    println!("\nserved {n_requests} requests ({n_distinct} distinct) in {wall:.1}s");
    println!(
        "  throughput: {:.2} req/s, {:.0} tokens/s",
        n_requests as f64 / wall,
        total_tokens as f64 / wall
    );
    let s = ttft.summary();
    println!(
        "  TTFT: mean {:.3}s p50 {:.3}s p95 {:.3}s p99 {:.3}s",
        s.mean, s.p50, s.p95, s.p99
    );
    println!(
        "  reuse: {:.1}% of tokens ({} chunks from DRAM, {} from SSD-spill)",
        100.0 * reused_tokens as f64 / total_tokens as f64,
        from_dram,
        from_ssd
    );
    let stats = exec.cache.stats;
    println!(
        "  cache: hit-ratio {:.1}%, dram evictions {}, inserts dram/ssd {}/{}",
        stats.hit_ratio() * 100.0,
        stats.evicted_chunks[Tier::Dram.idx()],
        stats.inserted_chunks[Tier::Dram.idx()],
        stats.inserted_chunks[Tier::Ssd.idx()],
    );
    anyhow::ensure!(reused_tokens > 0, "workload must exercise reuse");
    anyhow::ensure!(from_ssd > 0 || stats.evicted_chunks[Tier::Dram.idx()] == 0,
                    "if DRAM evicted, SSD path should serve something");

    // --- transfer-engine lane metrics (Fig 12's contention, on real
    // disk): prefetch-lane reads completed while prefill computed ---
    let io = exec.io_stats().expect("SSD tier is active");
    println!("\ntransfer engine (dual-lane, 2 workers):");
    println!("  {}", io.pretty().replace('\n', "\n  "));
    anyhow::ensure!(
        io.prefetch.submitted > 0 && io.prefetch.completed > 0,
        "prefetch lane must have moved chunks during the run"
    );
    anyhow::ensure!(
        io.demand.failed == 0 && io.prefetch.failed == 0,
        "no read may fail against the live spill directory"
    );

    // --- store integrity: the spill tier's absorbed-error counters,
    // surfaced through the shared degradation metrics ---
    let store = exec.store_stats().expect("SSD tier is active");
    let mut metrics = pcr::serve::metrics::MetricsCollector::new();
    metrics.record_store_errors(store.total());
    println!(
        "\nspill-store integrity: fsync_errors={} delete_errors={} \
         checksum_failures={} lost_files={} (degrade.store_errors={})",
        store.fsync_errors(),
        store.delete_errors(),
        store.checksum_failures(),
        store.lost_files(),
        metrics.report().degrade.store_errors,
    );
    anyhow::ensure!(store.total() == 0, "healthy run must absorb zero store errors");

    // --- upgrade demo: stage prefetches with the engine paused, then
    // serve that input — the demand submits claim the queued tickets,
    // so each chunk is read once, at demand priority ---
    exec.io_pause();
    let mut staged = 0usize;
    let mut target = None;
    for q in &queries {
        let input = retriever.retrieve(q);
        let n = exec.prefetch_chain(&ChunkedSeq::new(&input.tokens, exec.chunk_tokens));
        if n > 0 {
            staged = n;
            target = Some(input);
            break;
        }
    }
    if let Some(input) = target {
        let before = exec.io_stats().unwrap().upgraded;
        let r = exec.serve(&input.tokens)?; // serve resumes the engine
        let io = exec.io_stats().unwrap();
        println!(
            "\nupgrade demo: staged {staged} queued prefetches, served the same \
             input -> {} upgraded to demand priority ({} chunks via SSD, read once)",
            io.upgraded - before,
            r.reused_from_ssd
        );
        anyhow::ensure!(
            io.upgraded > before,
            "a demand fetch of an in-flight prefetch must upgrade, not re-read"
        );
    } else {
        println!("\nupgrade demo skipped: every chunk already DRAM-resident");
    }

    // cold-vs-warm speedup on a popular input
    let popular = retriever.retrieve(&queries[0]);
    let warm2 = exec.serve(&popular.tokens)?;
    println!(
        "\nwarm popular request: {:.3}s prefill, reused {}/{} tokens \
         (vs {:.3}s cold at request #1)",
        warm2.prefill_seconds,
        warm2.reused_tokens,
        popular.tokens.len(),
        cold.prefill_seconds
    );
    // --- persist mode: spill files survive shutdown, and a restarted
    // store checksum-verifies and adopts them (restart reconcile) ---
    drop(exec);
    let reconciled = pcr::cache::store::FileStore::new(&spill)?;
    println!(
        "\npersist mode: {} spill chunks ({} bytes) survived shutdown and \
         reconciled clean ({} checksum sweeps)",
        reconciled.keys().len(),
        reconciled.bytes_used(),
        reconciled.stats().checksum_failures(),
    );
    anyhow::ensure!(
        !reconciled.keys().is_empty(),
        "persist mode must keep spill files across Drop"
    );
    drop(reconciled); // this handle defaults persist off: sweeps the dir

    println!("\ne2e OK — record this run in EXPERIMENTS.md");
    Ok(())
}
